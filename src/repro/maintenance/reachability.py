"""The shared mark phase: what is *live* in a lake.

One reachability walk serves all three lakekeeper services (GC sweeps
against it, eviction releases roots from it, compaction relies on it to
expire superseded snapshots):

    roots                         edges
    -----                         -----
    branch heads  ─┐
    tags           ├─> commits ──> table manifests ──> shard column blobs
    pinned runs   ─┘
    stage-cache entries ─────────> table manifests ──> shard column blobs

Commits, branch heads, tags, pins and cache entries are *refs* (small
mutable pointers); manifests and column blobs are content-addressed
*objects*.  The mark returns both vocabularies: live commit ids (so the
GC can drop expired commit refs) and live object keys (so the sweep can
drop unreachable blobs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Set

from repro.catalog.nessie import Catalog
from repro.core.snapshot import RunRegistry, StageCacheRegistry
from repro.io.objectstore import ObjectStore
from repro.table.format import TableFormat


@dataclass(frozen=True)
class LiveSet:
    """The mark result: everything a sweep must keep."""

    #: live commit ids (reachable from branch heads/tags/pins within the
    #: history bound)
    commits: Set[str]
    #: live object keys (manifests + shard column blobs)
    objects: Set[str]
    #: telemetry: how many roots of each kind seeded the walk
    roots: Dict[str, int] = field(default_factory=dict)


def mark(
    store: ObjectStore,
    catalog: Catalog,
    fmt: TableFormat,
    *,
    history: Optional[int] = None,
    pin_ttl_s: Optional[float] = None,
) -> LiveSet:
    """Walk every root to a closed live set.

    ``history`` bounds how many commits deep each branch is retained
    (None = keep everything, ``1`` = heads only — Iceberg-style snapshot
    expiry).  Tagged commits are always roots regardless of depth, so a
    tag protects its data forever.  ``pin_ttl_s`` ages out pins leaked by
    crashed runs (None = honour all pins).
    """
    registry = RunRegistry(store)
    cache = StageCacheRegistry(store)

    pins = registry.pinned_commits(max_age_s=pin_ttl_s)
    commits = catalog.reachable_commits(
        extra_roots=list(pins.values()), history=history
    )

    manifests: Set[str] = set()
    for commit in commits.values():
        manifests.update(commit.tables.values())

    cache_entries = cache.entries()
    for entry in cache_entries.values():
        manifests.update(entry.outputs.values())

    objects: Set[str] = set()
    for key in manifests:
        objects |= fmt.snapshot_object_keys(key)

    return LiveSet(
        commits=set(commits),
        objects=objects,
        roots={
            "branches": len(catalog.branches()),
            "tags": len(catalog.tags()),
            "pinned_runs": len(pins),
            "cache_entries": len(cache_entries),
        },
    )
