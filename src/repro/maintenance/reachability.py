"""The shared mark phase: what is *live* in a lake.

One reachability walk serves all three lakekeeper services (GC sweeps
against it, eviction releases roots from it, compaction relies on it to
expire superseded snapshots):

    roots                         edges
    -----                         -----
    branch heads  ─┐
    tags           ├─> commits ──> table manifests ──> shard column blobs
    pinned runs   ─┘
    node-cache entries ──────────> table manifests ──> shard column blobs

Commits, branch heads, tags, pins and cache entries are *refs* (small
mutable pointers); manifests and column blobs are content-addressed
*objects*.  The mark returns both vocabularies: live commit ids (so the
GC can drop expired commit refs) and live object keys (so the sweep can
drop unreachable blobs).

Cache roots are **node-granular**: each live ``NodeCacheEntry`` (and any
not-yet-upgraded legacy stage entry — ``NodeCacheRegistry.entries()``
returns the union of both namespaces) pins the manifest of the one
artifact it caches, so evicting a single node releases exactly that
node's blobs to the next sweep.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Set

from repro.catalog.nessie import Catalog
from repro.core.snapshot import NodeCacheRegistry, RunRegistry
from repro.io.objectstore import ObjectStore
from repro.table.format import TableFormat


@dataclass(frozen=True)
class LiveSet:
    """The mark result: everything a sweep must keep."""

    #: live commit ids (reachable from branch heads/tags/pins within the
    #: history bound)
    commits: Set[str]
    #: live object keys (manifests + shard column blobs)
    objects: Set[str]
    #: telemetry: how many roots of each kind seeded the walk
    roots: Dict[str, int] = field(default_factory=dict)
    #: snapshot ids of the live manifests — lets the sweep prune
    #: content-fingerprint memo refs whose snapshot has been expired
    snapshot_ids: Set[str] = field(default_factory=set)


def mark(
    store: ObjectStore,
    catalog: Catalog,
    fmt: TableFormat,
    *,
    history: Optional[int] = None,
    pin_ttl_s: Optional[float] = None,
    runlog_ttl_s: Optional[float] = None,
) -> LiveSet:
    """Walk every root to a closed live set.

    ``history`` bounds how many commits deep each branch is retained
    (None = keep everything, ``1`` = heads only — Iceberg-style snapshot
    expiry).  Tagged commits are always roots regardless of depth, so a
    tag protects its data forever.  ``pin_ttl_s`` ages out pins leaked by
    crashed runs (None = honour all pins).  ``runlog_ttl_s`` bounds how
    long a persisted run trace (``runlog`` namespace) keeps its blob
    pinned — refs older than the TTL are *not* roots, so an expired
    trace's blob falls to the same pass's object sweep (None = every
    trace is a root).
    """
    registry = RunRegistry(store)
    cache = NodeCacheRegistry(store)

    pins = registry.pinned_commits(max_age_s=pin_ttl_s)
    commits = catalog.reachable_commits(
        extra_roots=list(pins.values()), history=history
    )

    manifests: Set[str] = set()
    for commit in commits.values():
        manifests.update(commit.tables.values())

    cache_entries = cache.entries()
    for entry in cache_entries.values():
        manifests.update(entry.outputs.values())

    # run traces are roots only within their retention TTL — an expired
    # trace's blob becomes unreachable and is reclaimed by the sweep
    from repro.telemetry.runlog import RunLogStore

    runlog_blobs = RunLogStore(store).live_blobs(ttl_s=runlog_ttl_s)

    objects: Set[str] = set(runlog_blobs.values())
    snapshot_ids: Set[str] = set()
    for key in manifests:
        # tolerate a missing manifest (crashed prior sweep), like
        # snapshot_object_keys does
        if not store.exists(key):
            continue
        snap = fmt.load_snapshot(key)
        snapshot_ids.add(snap.snapshot_id)
        objects.add(key)
        for shard in snap.shards:
            objects.update(shard.column_blobs.values())

    return LiveSet(
        commits=set(commits),
        objects=objects,
        roots={
            "branches": len(catalog.branches()),
            "tags": len(catalog.tags()),
            "pinned_runs": len(pins),
            "cache_entries": len(cache_entries),
            "runlogs": len(runlog_blobs),
        },
        snapshot_ids=snapshot_ids,
    )
