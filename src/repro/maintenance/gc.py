"""Mark-and-sweep garbage collection (``repro gc``).

Mark (repro.maintenance.reachability) walks branch heads, tags, pinned
in-flight runs and live stage-cache entries down to shard blobs; sweep
deletes everything else — first the unreachable/expired *commit refs*,
then the unreachable *objects* (manifests + column blobs).

Safety levers, in the order a production deployment reaches for them:

* ``dry_run``   — report what would be reclaimed, delete nothing;
* ``grace_s``   — never sweep an object younger than this, so an
  in-flight run's just-written, not-yet-committed stage outputs survive
  a concurrent sweep (defence in depth on top of run pins);
* ``history``   — Iceberg-style snapshot expiry: keep only the last N
  commits per branch (None keeps all history, so a default ``repro gc``
  only reclaims failed/abandoned runs and evicted cache blobs);
* ``pin_ttl_s`` — how long a leaked pin (crashed process) keeps
  protecting its base commit.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional

from repro.catalog.nessie import Catalog
from repro.io.objectstore import ObjectStore
from repro.maintenance.reachability import LiveSet, mark
from repro.table.format import TableFormat
from repro.utils.logging import get_logger

log = get_logger("maintenance.gc")


@dataclass(frozen=True)
class GCReport:
    """What one ``repro gc`` pass saw and did."""

    roots: Dict[str, int]
    live_commits: int
    live_objects: int
    swept_commits: int
    swept_objects: int
    bytes_reclaimed: int
    #: unreachable but younger than the grace period — left for next time
    kept_young: int
    dry_run: bool
    #: content-fingerprint memo refs pruned for expired snapshots
    swept_content_refs: int = 0
    #: speculation latency baselines dropped for long-unused fingerprints
    swept_latency_refs: int = 0
    #: run-trace refs expired past the runlog retention TTL
    swept_runlog_refs: int = 0

    def describe(self) -> str:
        verb = "would reclaim" if self.dry_run else "reclaimed"
        return (
            f"gc: {verb} {self.swept_objects} objects "
            f"({self.bytes_reclaimed} bytes) + {self.swept_commits} commit refs "
            f"+ {self.swept_content_refs} content-hash memos "
            f"+ {self.swept_latency_refs} latency baselines "
            f"+ {self.swept_runlog_refs} run traces; "
            f"live: {self.live_commits} commits / {self.live_objects} objects; "
            f"spared {self.kept_young} in-grace objects; roots: {self.roots}"
        )


def collect_garbage(
    store: ObjectStore,
    catalog: Catalog,
    fmt: TableFormat,
    *,
    history: Optional[int] = None,
    grace_s: float = 0.0,
    pin_ttl_s: Optional[float] = None,
    latency_ttl_s: Optional[float] = 30 * 86400.0,
    runlog_ttl_s: Optional[float] = 14 * 86400.0,
    dry_run: bool = False,
    bus=None,
) -> GCReport:
    """One full mark-and-sweep pass.  Idempotent and crash-safe: every
    delete is a no-op when re-applied, and a half-finished sweep only
    leaves garbage for the next pass, never dangling live data.

    ``runlog_ttl_s`` is the run-trace retention window (``repro gc
    --runlog-ttl``): traces older than it lose their ref here, and their
    blobs — no longer reachability roots — fall to this same pass's
    object sweep.  ``None`` keeps every trace.  ``bus`` (an optional
    :class:`repro.telemetry.bus.EventBus`) gets one ``GcSweep`` event
    summarizing the pass.
    """
    live: LiveSet = mark(
        store, catalog, fmt, history=history, pin_ttl_s=pin_ttl_s,
        runlog_ttl_s=runlog_ttl_s,
    )

    # drop expired run-trace refs BEFORE the object sweep: the mark above
    # already excluded them from the live set, so their blobs reclaim in
    # this very pass (ref sweep + blob sweep, one gc invocation)
    swept_runlogs = 0
    if runlog_ttl_s is not None:
        from repro.telemetry.runlog import RunLogStore

        swept_runlogs = RunLogStore(store).sweep_expired(
            ttl_s=runlog_ttl_s, dry_run=dry_run
        )

    # sweep expired/unreachable commit refs first so a crash between the
    # two phases can't leave a commit whose objects are already gone.
    # The grace period applies here too: a concurrent run writes its
    # commit ref *before* CAS-ing the branch head, so a just-created
    # commit can look unreachable for a moment — deleting it would leave
    # the branch head dangling once the CAS lands.
    now = time.time()
    swept_commits = 0
    for commit_id in catalog.all_commit_ids():
        if commit_id in live.commits:
            continue
        commit = catalog.get_commit_opt(commit_id)
        if commit is not None and now - commit.created_at < grace_s:
            continue
        swept_commits += 1
        if not dry_run:
            catalog.delete_commit(commit_id)

    result = store.sweep(
        live.objects, grace_s=grace_s, dry_run=dry_run
    )

    # content-fingerprint memos for expired snapshots are pure cache —
    # dropping one only costs a recompute on next use, so no grace needed
    swept_content = fmt.prune_content_fingerprints(
        live.snapshot_ids, dry_run=dry_run
    )

    # speculation latency baselines (written by the SDK Client) are keyed
    # by *function* fingerprint — every code edit mints a new one and no
    # catalog walk can prove liveness, so they expire by disuse: a ref not
    # refreshed for latency_ttl_s belongs to code nobody runs anymore.
    # Pure telemetry cache — dropping one costs a re-learned baseline.
    swept_latency = 0
    if latency_ttl_s is not None:
        for name, raw in store.list_refs("latencyhist").items():
            if now - raw.get("updated_at", 0.0) > latency_ttl_s:
                swept_latency += 1
                if not dry_run:
                    store.delete_ref("latencyhist", name)

    report = GCReport(
        roots=live.roots,
        live_commits=len(live.commits),
        live_objects=len(live.objects),
        swept_commits=swept_commits,
        swept_objects=result.swept,
        bytes_reclaimed=result.bytes_reclaimed,
        kept_young=result.kept_young,
        dry_run=dry_run,
        swept_content_refs=swept_content,
        swept_latency_refs=swept_latency,
        swept_runlog_refs=swept_runlogs,
    )
    log.info("%s", report.describe())
    if bus is not None:
        from repro.telemetry.events import GcSweep

        bus.publish(GcSweep(
            swept_objects=report.swept_objects,
            swept_commits=report.swept_commits,
            swept_runlog_refs=report.swept_runlog_refs,
            bytes_reclaimed=report.bytes_reclaimed,
            dry_run=dry_run,
        ))
    return report
