"""Small-shard compaction (``repro compact``).

Many small appends (or a runner writing per-stage outputs with a small
``shard_rows``) leave tables fragmented: every scan pays per-shard
overhead (one object GET per column per shard) and per-shard min/max
stats prune less than they could.  Compaction rewrites runs of adjacent
small shards into fewer near-target ones **as a new commit**:

* row order is preserved, so query results are bit-identical;
* per-column min/max stats are recomputed from the merged data, so
  ``Predicate.may_match`` pruning stays exact (``pruning_effectiveness``
  quantifies it before/after on the table's hot predicates);
* the old snapshot stays readable (time travel, replay of pinned runs)
  until ``repro gc --history N`` expires the commit that references it —
  compaction creates garbage, GC collects it, exactly Iceberg's
  rewrite-then-expire split.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.catalog.nessie import Catalog
from repro.table.format import TableFormat, plan_compaction_groups
from repro.table.scan import Predicate, pruning_effectiveness
from repro.utils.logging import get_logger

log = get_logger("maintenance.compaction")


@dataclass(frozen=True)
class CompactionReport:
    table: str
    branch: str
    shards_before: int
    shards_after: int
    #: small shards rewritten into merged ones (0 = table already compact)
    shards_merged: int
    #: commit that published the compacted snapshot (None on no-op/dry-run)
    commit_id: Optional[str]
    #: metadata-only pruning fraction on guard predicates, before/after
    pruning_before: Optional[float]
    pruning_after: Optional[float]
    dry_run: bool

    def describe(self) -> str:
        if self.shards_merged == 0:
            return f"compact {self.table}@{self.branch}: already compact"
        verb = "would rewrite" if self.dry_run else "rewrote"
        extra = ""
        if self.pruning_before is not None:
            extra = (
                f"; pruning {self.pruning_before:.0%} -> {self.pruning_after:.0%}"
            )
        return (
            f"compact {self.table}@{self.branch}: {verb} "
            f"{self.shards_merged} small shards, "
            f"{self.shards_before} -> {self.shards_after} shards{extra}"
        )


def _publish_compaction(bus, report: "CompactionReport") -> None:
    if bus is None:
        return
    from repro.telemetry.events import CompactionApplied

    bus.publish(CompactionApplied(
        table=report.table,
        branch=report.branch,
        shards_before=report.shards_before,
        shards_after=report.shards_after,
        shards_merged=report.shards_merged,
        dry_run=report.dry_run,
    ))


def compact_table(
    catalog: Catalog,
    fmt: TableFormat,
    table: str,
    *,
    branch: str = "main",
    target_rows: Optional[int] = None,
    min_fill: float = 0.5,
    guard_predicates: Sequence[Predicate] = (),
    author: str = "lakekeeper",
    dry_run: bool = False,
    bus=None,
) -> CompactionReport:
    """Compact one table at a branch head into a new commit.  ``bus`` (an
    optional EventBus) gets one ``CompactionApplied`` per report."""
    key = catalog.table_key(table, branch=branch)
    snap = fmt.load_snapshot(key)
    target = target_rows or fmt.shard_rows

    if dry_run:
        groups = plan_compaction_groups(
            snap.shards, target_rows=target, min_fill=min_fill
        )
        merged = sum(len(g) for g in groups if len(g) > 1)
        report = CompactionReport(
            table=table,
            branch=branch,
            shards_before=len(snap.shards),
            shards_after=len(groups) if merged else len(snap.shards),
            shards_merged=merged,
            commit_id=None,
            pruning_before=(
                pruning_effectiveness(snap, guard_predicates)
                if guard_predicates else None
            ),
            pruning_after=None,
            dry_run=True,
        )
        log.info("%s", report.describe())
        _publish_compaction(bus, report)
        return report

    new_snap, merged = fmt.compact_snapshot(
        snap, target_rows=target, min_fill=min_fill
    )
    commit_id = None
    pruning_before = pruning_after = None
    if guard_predicates:
        pruning_before = pruning_effectiveness(snap, guard_predicates)
        pruning_after = pruning_effectiveness(new_snap, guard_predicates)
        if pruning_after < pruning_before:
            log.warning(
                "compact %s@%s coarsened pushdown on guard predicates "
                "(%.0f%% -> %.0f%% rows pruned) — consider a smaller "
                "--target-rows for this table",
                table, branch, 100 * pruning_before, 100 * pruning_after,
            )
    if merged:
        # table-level CAS: this rewrite is only valid against the exact
        # version we read — a concurrent run merging new rows must win,
        # raising MergeConflict here (rerun compaction; the orphaned
        # rewritten shards are swept by the next gc)
        commit = catalog.commit(
            branch,
            {table: fmt.manifest_key(new_snap)},
            message=(
                f"compact {table}: {len(snap.shards)} -> "
                f"{len(new_snap.shards)} shards"
            ),
            author=author,
            expect={table: key},
        )
        commit_id = commit.commit_id
        fmt.store.bump_stat("compact_shards_merged", merged)
    report = CompactionReport(
        table=table,
        branch=branch,
        shards_before=len(snap.shards),
        shards_after=len(new_snap.shards),
        shards_merged=merged,
        commit_id=commit_id,
        pruning_before=pruning_before,
        pruning_after=pruning_after,
        dry_run=False,
    )
    log.info("%s", report.describe())
    _publish_compaction(bus, report)
    return report


def compact_branch(
    catalog: Catalog,
    fmt: TableFormat,
    *,
    branch: str = "main",
    target_rows: Optional[int] = None,
    min_fill: float = 0.5,
    author: str = "lakekeeper",
    dry_run: bool = False,
    bus=None,
) -> List[CompactionReport]:
    """Compact every table at a branch head (the cron-job entry point)."""
    return [
        compact_table(
            catalog, fmt, table,
            branch=branch, target_rows=target_rows, min_fill=min_fill,
            author=author, dry_run=dry_run, bus=bus,
        )
        for table in sorted(catalog.tables(branch=branch))
    ]
