"""Lakekeeper — the lake-maintenance subsystem.

The lakehouse's write path is append-only by design: blobs are
content-addressed and immutable, commits chain forever, the differential
cache grows monotonically.  That is what makes branches, time travel and
replay trivially correct (paper 4.3/4.4) — and also what makes a real
deployment leak storage without bound.  Lakekeeper is the counterpart
service every production lakehouse runs (Iceberg snapshot expiry + small
file compaction; see arXiv 2310.08697, and arXiv 2411.08203 for why a
differential cache must be budgeted):

* ``repro.maintenance.reachability`` — the shared mark phase: walk roots
  (branch heads, tags, live cache entries, pinned in-flight runs) through
  commits -> snapshot manifests -> shard blobs;
* ``repro.maintenance.gc``          — mark-and-sweep garbage collection
  with dry-run, history expiry and an in-flight grace period;
* ``repro.maintenance.eviction``    — LRU/TTL cache eviction under a byte
  budget (evicted entries release their blobs to the sweeper);
* ``repro.maintenance.compaction``  — small-shard compaction as a new
  catalog commit, old snapshots stay readable until expired.
"""
from repro.maintenance.reachability import LiveSet, mark
from repro.maintenance.gc import GCReport, collect_garbage
from repro.maintenance.eviction import EvictionPolicy, EvictionReport, prune_cache
from repro.maintenance.compaction import CompactionReport, compact_table, compact_branch

__all__ = [
    "LiveSet",
    "mark",
    "GCReport",
    "collect_garbage",
    "EvictionPolicy",
    "EvictionReport",
    "prune_cache",
    "CompactionReport",
    "compact_table",
    "compact_branch",
]
