"""Pallas kernel: fused filter + grouped aggregation in one VMEM pass.

TPU adaptation of the paper's 4.4.2 fusion (filter pushdown + in-place
aggregation).  A CPU engine would stream rows through a predicate then a
hash aggregate; on TPU we instead:

* tile the row stream into ``(ROWS, 128)`` VMEM blocks (lane-aligned);
* evaluate the predicate vectorized on the VPU;
* aggregate WITHOUT scatters: compare keys against the group lane axis
  (a dense one-hot over ``(rows, lanes, groups)``) and contract — this
  maps onto dense vector/matrix units instead of random HBM updates;
* exploit the TPU's *sequential* grid to accumulate partial (sums,
  counts) into a revisited output block, initialised at grid step 0.

VMEM budget per step (defaults ROWS=8, G=256):
  keys/vals/filt blocks: 3 × 8×128×4B = 12 KB
  one-hot intermediate:  8×128×256×4B = 1 MB
  accumulators:          2 × 256×4B   = 2 KB          → ~1 MB « 16 MB.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: sublane rows per grid step (block covers ROWS×128 elements)
DEFAULT_BLOCK_ROWS = 8


def _predicate(filt: jax.Array, op: str, threshold: float) -> jax.Array:
    t = jnp.asarray(threshold, filt.dtype)
    return {
        "ge": filt >= t,
        "gt": filt > t,
        "le": filt <= t,
        "lt": filt < t,
        "eq": filt == t,
        "ne": filt != t,
    }[op]


def _kernel(
    keys_ref,      # (ROWS, 128) int32
    vals_ref,      # (ROWS, 128) f32
    filt_ref,      # (ROWS, 128) f32
    sums_ref,      # (1, G) f32 accumulator (revisited block)
    counts_ref,    # (1, G) f32 accumulator
    *,
    op: str,
    threshold: float,
    num_groups: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    keys = keys_ref[...]
    mask = _predicate(filt_ref[...], op, threshold)
    vals = jnp.where(mask, vals_ref[...].astype(jnp.float32), 0.0)
    ones = mask.astype(jnp.float32)

    # dense one-hot over the group axis: (ROWS, 128, G); padded rows carry
    # key == -1 and match nothing.
    group_iota = jax.lax.broadcasted_iota(jnp.int32, keys.shape + (num_groups,), 2)
    onehot = (keys[..., None] == group_iota).astype(jnp.float32)

    sums_ref[...] += jnp.einsum(
        "rcg,rc->g", onehot, vals, preferred_element_type=jnp.float32
    )[None, :]
    counts_ref[...] += jnp.einsum(
        "rcg,rc->g", onehot, ones, preferred_element_type=jnp.float32
    )[None, :]


def fused_filter_agg_kernel(
    keys2d: jax.Array,   # (R, 128) int32, padded rows = -1
    vals2d: jax.Array,   # (R, 128) f32
    filt2d: jax.Array,   # (R, 128) f32
    *,
    op: str,
    threshold: float,
    num_groups: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    rows = keys2d.shape[0]
    assert rows % block_rows == 0, (rows, block_rows)
    assert num_groups % 128 == 0, "group axis must be lane-aligned"
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        functools.partial(
            _kernel, op=op, threshold=threshold, num_groups=num_groups
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, num_groups), lambda i: (0, 0)),
            pl.BlockSpec((1, num_groups), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, num_groups), jnp.float32),
            jax.ShapeDtypeStruct((1, num_groups), jnp.float32),
        ],
        interpret=interpret,
    )(keys2d, vals2d, filt2d)
    return out[0][0], out[1][0]
