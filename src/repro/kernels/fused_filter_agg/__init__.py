from repro.kernels.fused_filter_agg.ops import fused_filter_agg
from repro.kernels.fused_filter_agg.ref import fused_filter_agg_ref

__all__ = ["fused_filter_agg", "fused_filter_agg_ref"]
