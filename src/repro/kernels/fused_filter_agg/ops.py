"""Public jit'd wrapper: padding/reshaping around the Pallas kernel."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_filter_agg.kernel import (
    DEFAULT_BLOCK_ROWS,
    fused_filter_agg_kernel,
)

_LANES = 128


@functools.partial(
    jax.jit,
    static_argnames=("op", "threshold", "num_groups", "block_rows", "interpret"),
)
def fused_filter_agg(
    keys: jax.Array,        # int32[n]
    values: jax.Array,      # float[n]
    filter_vals: jax.Array,  # float[n]
    *,
    op: str = "ge",
    threshold: float = 0.0,
    num_groups: int = 256,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Grouped (sum, count) over rows passing the predicate — one fused pass.

    Pads the row stream to a whole number of (block_rows × 128) tiles and
    lane-aligns the group axis; padded rows carry key ``-1`` (matches no
    group) so they contribute nothing.
    """
    n = keys.shape[0]
    g_pad = -num_groups % _LANES
    num_groups_padded = num_groups + g_pad
    tile = block_rows * _LANES
    n_pad = -n % tile
    keys_p = jnp.pad(keys.astype(jnp.int32), (0, n_pad), constant_values=-1)
    vals_p = jnp.pad(values.astype(jnp.float32), (0, n_pad))
    filt_p = jnp.pad(filter_vals.astype(jnp.float32), (0, n_pad))
    rows = (n + n_pad) // _LANES
    sums, counts = fused_filter_agg_kernel(
        keys_p.reshape(rows, _LANES),
        vals_p.reshape(rows, _LANES),
        filt_p.reshape(rows, _LANES),
        op=op,
        threshold=threshold,
        num_groups=num_groups_padded,
        block_rows=block_rows,
        interpret=interpret,
    )
    return sums[:num_groups], counts[:num_groups]
