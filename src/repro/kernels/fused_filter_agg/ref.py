"""Pure-jnp oracle for the fused filter + grouped aggregation."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_OPS = ("ge", "gt", "le", "lt", "eq", "ne")


def _mask(filter_vals: jax.Array, op: str, threshold: float) -> jax.Array:
    t = jnp.asarray(threshold, filter_vals.dtype)
    if op == "ge":
        return filter_vals >= t
    if op == "gt":
        return filter_vals > t
    if op == "le":
        return filter_vals <= t
    if op == "lt":
        return filter_vals < t
    if op == "eq":
        return filter_vals == t
    if op == "ne":
        return filter_vals != t
    raise ValueError(f"op must be one of {_OPS}, got {op!r}")


def fused_filter_agg_ref(
    keys: jax.Array,       # int32[n] group ids in [0, num_groups)
    values: jax.Array,     # float[n]
    filter_vals: jax.Array,  # float[n] — predicate column
    *,
    op: str,
    threshold: float,
    num_groups: int,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (sums f32[num_groups], counts f32[num_groups]) over rows
    passing ``filter_vals <op> threshold`` — one logical pass, no
    intermediate filtered table."""
    mask = _mask(filter_vals, op, threshold)
    vals = jnp.where(mask, values.astype(jnp.float32), 0.0)
    ones = mask.astype(jnp.float32)
    sums = jnp.zeros((num_groups,), jnp.float32).at[keys].add(vals, mode="drop")
    counts = jnp.zeros((num_groups,), jnp.float32).at[keys].add(ones, mode="drop")
    return sums, counts
