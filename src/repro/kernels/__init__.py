"""Pallas TPU kernels for the framework's compute hot spots.

Three kernels, each a `<name>/` subpackage with:

* ``kernel.py`` — the pl.pallas_call body with explicit BlockSpec VMEM tiling
* ``ops.py``    — the jit'd public wrapper (padding, reshaping, GQA mapping)
* ``ref.py``    — the pure-jnp oracle the tests sweep against

1. ``fused_filter_agg`` — the paper's 4.4.2 optimization as a single VMEM
   pass: predicate + masked grouped aggregation without materializing the
   filtered intermediate.  TPU adaptation of a row-wise CPU pipeline:
   one-hot compare against the group lane axis, block-accumulated over a
   sequential grid (no scatter — dense MXU/VPU-friendly ops).
2. ``flash_attention`` — blockwise online-softmax causal attention
   (training + prefill), with optional sliding window (SWA archs).
3. ``decode_attention`` — single-token attention against a long KV cache,
   S-blocked with running-max/denominator accumulators (serving).

Kernels are validated in interpret mode on CPU (the container has no TPU);
the pure-JAX reference path is the default in the models so numerical
behaviour is platform-independent, with kernels switchable via config.
"""
