"""Pallas TPU kernels for the framework's compute hot spots.

Three kernels, each a `<name>/` subpackage with:

* ``kernel.py`` — the pl.pallas_call body with explicit BlockSpec VMEM tiling
* ``ops.py``    — the jit'd public wrapper (padding, reshaping, GQA mapping)
* ``ref.py``    — the pure-jnp oracle the tests sweep against

1. ``fused_filter_agg`` — the paper's 4.4.2 optimization as a single VMEM
   pass: predicate + masked grouped aggregation without materializing the
   filtered intermediate.  TPU adaptation of a row-wise CPU pipeline:
   one-hot compare against the group lane axis, block-accumulated over a
   sequential grid (no scatter — dense MXU/VPU-friendly ops).
2. ``flash_attention`` — blockwise online-softmax causal attention
   (training + prefill), with optional sliding window (SWA archs).
3. ``decode_attention`` — single-token attention against a long KV cache,
   S-blocked with running-max/denominator accumulators (serving).

Kernels are validated in interpret mode on CPU (the container has no TPU);
the pure-JAX reference path remains available everywhere and kernels are
switchable via config.

Routing (when does a query actually hit ``fused_filter_agg``?)
--------------------------------------------------------------
Since SQL v2 the kernel is wired into the query engine: the planner
(``core/physical.py``) and the interactive path (``Runner.query``) ask
``engine/route.py`` for a :class:`RouteDecision` per aggregation query.
Under the default ``engine="auto"`` a query routes to the kernel only
when the decision is *provably byte-identical* to the jnp reference:

* shape: exactly one GROUP BY key, aggregates ⊆ {COUNT, SUM, MEAN}, and
  non-COUNT aggregate arguments are plain column references;
* key: integer/bool dtype with shard-stats min/max known and a value
  range ≤ 1024 groups (LEFT JOINs widen the range to include the 0
  fill value);
* exactness: all values integer-typed and small enough that their f32
  sums stay exact (< 2^24) — float columns never auto-route because
  f32 re-association changes low bits;
* filter: fused natively only for a single ``col <op> literal`` whose
  column stats prove f32-exact compare; any other predicate is
  evaluated by the jnp expression tree and fed to the kernel as a mask.

``engine="kernel"`` forces the route (structural impossibility raises
``RouteError``); ``engine="jnp"`` pins the reference path.  Routing is
never part of node fingerprints — both engines produce byte-identical
artifacts, so cache entries stay warm across engine switches.
"""
