"""Pallas kernel: blockwise online-softmax (flash) attention for TPU.

Tiling strategy (per grid step = one (batch·head, q-block) pair):

* the q block ``(BQ, D)`` plus the head's full K/V ``(S, D)`` live in
  VMEM — at the training shape (S=4096, D=128, bf16) that's 1 MB q + 2 MB
  K/V, comfortably inside the 16 MB v5e budget;
* the kv axis is walked in ``BK`` chunks with the standard running
  (max, denominator, accumulator) online-softmax recurrence in f32;
* causality/sliding windows skip whole chunks: the fori upper bound is
  the last visible chunk for this q block, so past-the-diagonal work is
  never issued (≈2× FLOP saving vs masked full attention);
* MXU alignment: BQ/BK multiples of the 128 lane dim; D = head_dim is
  128 on every assigned architecture.

GQA: the wrapper maps each q head to its kv head in the BlockSpec index
map — no repeat/materialization of K/V (HBM traffic stays at kv=K heads,
the GQA point).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
_NEG_INF = -1e30


def _kernel(
    q_ref,   # (1, BQ, D)
    k_ref,   # (1, S, D)
    v_ref,   # (1, S, D)
    o_ref,   # (1, BQ, D)
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_k: int,
    seq_len: int,
):
    qi = pl.program_id(1)
    bq = q_ref.shape[1]
    d = q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)

    q_start = qi * bq
    row_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    if causal:
        # last kv chunk any row in this q block can see
        hi = jax.lax.div(q_start + bq - 1, block_k) + 1
    else:
        hi = seq_len // block_k
    if window is not None:
        lo = jnp.maximum(jax.lax.div(q_start - window + 1, block_k), 0)
    else:
        lo = 0

    def body(kc, carry):
        acc, m, l = carry
        k_chunk = k_ref[0, pl.dslice(kc * block_k, block_k), :].astype(jnp.float32)
        v_chunk = v_ref[0, pl.dslice(kc * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_chunk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (BQ, BK)
        col_ids = kc * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1
        )
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= col_ids <= row_ids
        if window is not None:
            mask &= col_ids > row_ids - window
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + jnp.sum(p, axis=1)
        acc_new = acc * correction[:, None] + jax.lax.dot_general(
            p, v_chunk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (B*H, S, D)
    k: jax.Array,  # (B*Hkv, S, D)
    v: jax.Array,  # (B*Hkv, S, D)
    *,
    group: int,  # H // Hkv — q head i reads kv head i // group
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    bh, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    grid = (bh, s // block_q)
    return pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            causal=causal,
            window=window,
            block_k=block_k,
            seq_len=s,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            # GQA mapping happens here: q head -> shared kv head
            pl.BlockSpec((1, s, d), lambda i, j, g=group: (i // g, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j, g=group: (i // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
