"""Public jit'd wrapper for the flash attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_kernel,
)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0, f"GQA needs H({h}) % Hkv({hkv}) == 0"
    group = h // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    bq = min(block_q, s)
    bk = min(block_k, s)
    out = flash_attention_kernel(
        q.reshape(b * h, s, d),
        k.reshape(b * hkv, s, d),
        v.reshape(b * hkv, s, d),
        group=group,
        scale=scale,
        causal=causal,
        window=window,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
    )
    return out.reshape(b, h, s, d)
