"""Pure-jnp oracle: causal (optionally sliding-window) attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding window size (None = full)
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, s, d = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    scores = jnp.where(mask, scores, -jnp.inf)
    weights = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", weights, v.astype(jnp.float32))
    return out.astype(q.dtype)
