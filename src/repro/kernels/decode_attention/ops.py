"""Public jit'd wrapper for decode attention (GQA + ragged lengths)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    DEFAULT_BLOCK_S,
    decode_attention_kernel,
)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "interpret")
)
def decode_attention(
    q: jax.Array,        # (B, H, D)
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    lengths: jax.Array,  # (B,) int32
    *,
    scale: Optional[float] = None,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    assert h % hkv == 0
    group = h // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    bs = min(block_s, s)
    lengths_bh = jnp.broadcast_to(lengths[:, None], (b, h)).reshape(b * h, 1)
    out = decode_attention_kernel(
        q.reshape(b * h, 1, d),
        k_cache.reshape(b * hkv, s, d),
        v_cache.reshape(b * hkv, s, d),
        lengths_bh.astype(jnp.int32),
        group=group,
        scale=scale,
        block_s=bs,
        interpret=interpret,
    )
    return out.reshape(b, h, d).astype(q.dtype)
