"""Pure-jnp oracle: one-token attention over a (possibly padded) KV cache."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,        # (B, H, D) — the single new token's queries
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    lengths: jax.Array,  # (B,) int32 — valid cache entries per sequence
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = h // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    k = jnp.repeat(k_cache, group, axis=1)
    v = jnp.repeat(v_cache, group, axis=1)
    scores = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    valid = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    weights = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", weights, v.astype(jnp.float32))
    return out.astype(q.dtype)
