"""Pallas kernel: single-token decode attention against a long KV cache.

Decode is memory-bound: each step must stream the whole KV cache from HBM
once, and arithmetic intensity is O(1).  The tiling therefore optimizes
for streaming, not reuse:

* grid = (batch·heads, S/BS): the cache axis is *grid-blocked* — unlike
  prefill, a 500k-token cache (128 GB global, ~8 MB per head-block slice)
  must never sit in VMEM at once; each step touches one ``(BS, D)`` chunk;
* the online-softmax running state (numerator (1,D), denominator+max
  (1,1)) lives in small revisited output blocks — the TPU sequential grid
  makes the recurrence exact;
* the final grid step for each (b,h) normalizes numerator/denominator
  in-place, so no extra pass over the output is needed;
* cache entries past ``length`` (ragged batches) are masked by comparing
  the chunk's global positions against the per-sequence length carried in
  a scalar-prefetch-style (1,1) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 1024
_NEG_INF = -1e30


def _kernel(
    len_ref,   # (1, 1) int32 — valid length for this sequence
    q_ref,     # (1, 1, D)
    k_ref,     # (1, BS, D)
    v_ref,     # (1, BS, D)
    o_ref,     # (1, 1, D)  — numerator accumulator, normalized at the end
    m_ref,     # (1, 1) f32 — running max
    l_ref,     # (1, 1) f32 — running denominator
    *,
    scale: float,
    block_s: int,
    num_s_blocks: int,
):
    sc = pl.program_id(1)

    @pl.when(sc == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (1, D)
    k = k_ref[0].astype(jnp.float32)                # (BS, D)
    v = v_ref[0].astype(jnp.float32)                # (BS, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (1, BS)
    pos = sc * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    s = jnp.where(pos < len_ref[0, 0], s, _NEG_INF)

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                           # (1, BS)
    correction = jnp.exp(m_prev - m_new)
    l_new = l_prev * correction + jnp.sum(p)
    acc = o_ref[0].astype(jnp.float32) * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new

    # numerator stays f32 across chunks (o_ref dtype is f32 by contract);
    # the wrapper casts the final normalized value back to q.dtype
    @pl.when(sc == num_s_blocks - 1)
    def _finalize():
        o_ref[0] = acc / jnp.maximum(l_new, 1e-30)

    @pl.when(sc != num_s_blocks - 1)
    def _stash():
        o_ref[0] = acc


def decode_attention_kernel(
    q: jax.Array,        # (B*H, 1, D)
    k_cache: jax.Array,  # (B*Hkv, S, D)
    v_cache: jax.Array,  # (B*Hkv, S, D)
    lengths: jax.Array,  # (B*H, 1) int32 (pre-broadcast per q head)
    *,
    group: int,
    scale: float,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    bh, _, d = q.shape
    s = k_cache.shape[1]
    assert s % block_s == 0, (s, block_s)
    num_s_blocks = s // block_s
    grid = (bh, num_s_blocks)
    out, _, _ = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, block_s=block_s, num_s_blocks=num_s_blocks
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, g=group: (i // g, j, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, g=group: (i // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
    return out
