"""Git-like semantics for data: branches, commits, merges, ephemeral runs.

Faithful to the paper's workflow (4.3, Fig. 4):

1. user works on a code branch ``feat_1`` → catalog branch ``feat_1`` is
   created from ``main``;
2. each ``run`` executes in an **ephemeral branch** (``run_<id>``) forked
   from the working branch;
3. only if every step and every expectation succeeds is the ephemeral
   branch **merged** back (atomic, transaction-like); otherwise it is
   discarded and production data is never dirtied;
4. the ephemeral branch is deleted after the merge.

Commits are immutable content-addressed objects in the ObjectStore;
branch heads are CAS-updated refs, so concurrent writers cannot silently
clobber each other (optimistic concurrency, like Nessie).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.io.objectstore import ObjectStore
from repro.io.serialization import dumps_json, loads_json
from repro.utils.hashing import stable_hash

_BRANCH_NS = "branches"
_TAG_NS = "tags"


class CatalogError(RuntimeError):
    pass


class MergeConflict(CatalogError):
    """Raised when both branches changed the same table since their base."""


@dataclass(frozen=True)
class Commit:
    """An immutable catalog state: {table name -> snapshot manifest key}."""

    commit_id: str
    parent_id: Optional[str]
    tables: Dict[str, str]
    message: str
    author: str
    created_at: float
    extra_parent_id: Optional[str] = None  # for merge commits

    def to_json_dict(self) -> Dict:
        return {
            "commit_id": self.commit_id,
            "parent_id": self.parent_id,
            "tables": self.tables,
            "message": self.message,
            "author": self.author,
            "created_at": self.created_at,
            "extra_parent_id": self.extra_parent_id,
        }

    @staticmethod
    def from_json_dict(d: Dict) -> "Commit":
        return Commit(
            commit_id=d["commit_id"],
            parent_id=d.get("parent_id"),
            tables=dict(d["tables"]),
            message=d.get("message", ""),
            author=d.get("author", ""),
            created_at=d.get("created_at", 0.0),
            extra_parent_id=d.get("extra_parent_id"),
        )


@dataclass
class Catalog:
    store: ObjectStore
    default_branch: str = "main"

    def __post_init__(self) -> None:
        if self.store.get_ref(_BRANCH_NS, self.default_branch) is None:
            root = self._write_commit(
                parent_id=None, tables={}, message="init", author="system"
            )
            self.store.set_ref(_BRANCH_NS, self.default_branch, {"commit": root.commit_id})

    # -------------------------------------------------------------- commits
    def _write_commit(
        self,
        *,
        parent_id: Optional[str],
        tables: Dict[str, str],
        message: str,
        author: str,
        extra_parent_id: Optional[str] = None,
    ) -> Commit:
        created = time.time()
        commit_id = stable_hash(
            {
                "parent": parent_id,
                "tables": tables,
                "message": message,
                "author": author,
                "created": created,
                "extra": extra_parent_id,
            },
            length=32,
        )
        commit = Commit(commit_id, parent_id, dict(tables), message, author,
                        created, extra_parent_id)
        self.store.set_ref("commits", commit_id, commit.to_json_dict())
        return commit

    def get_commit(self, commit_id: str) -> Commit:
        raw = self.store.get_ref("commits", commit_id)
        if raw is None:
            raise CatalogError(f"no such commit {commit_id}")
        return Commit.from_json_dict(raw)

    def get_commit_opt(self, commit_id: Optional[str]) -> Optional[Commit]:
        """Like ``get_commit`` but None for a missing/expired commit.

        After ``repro gc`` expires old history, a surviving commit's
        parent pointer may dangle; walks treat that as the history
        horizon (like a shallow git clone) rather than corruption.
        """
        if commit_id is None:
            return None
        raw = self.store.get_ref("commits", commit_id)
        return None if raw is None else Commit.from_json_dict(raw)

    def delete_commit(self, commit_id: str) -> bool:
        """Remove a commit ref (GC of expired/unreachable history)."""
        return self.store.delete_ref("commits", commit_id)

    def all_commit_ids(self) -> List[str]:
        """Every commit ref in the store, reachable or not."""
        return sorted(self.store.list_refs("commits").keys())

    # ------------------------------------------------------------- branches
    def branches(self) -> List[str]:
        return sorted(self.store.list_refs(_BRANCH_NS).keys())

    def head(self, branch: str) -> Commit:
        ref = self.store.get_ref(_BRANCH_NS, branch)
        if ref is None:
            raise CatalogError(f"no such branch {branch!r}")
        return self.get_commit(ref["commit"])

    def create_branch(
        self,
        name: str,
        *,
        from_branch: Optional[str] = None,
        at_commit: Optional[str] = None,
    ) -> Commit:
        """Fork a branch from another branch's head or any commit
        (``at_commit`` enables replaying runs against historical data)."""
        if self.store.get_ref(_BRANCH_NS, name) is not None:
            raise CatalogError(f"branch {name!r} already exists")
        base = (
            self.get_commit(at_commit)
            if at_commit is not None
            else self.head(from_branch or self.default_branch)
        )
        self.store.set_ref(_BRANCH_NS, name, {"commit": base.commit_id})
        return base

    def delete_branch(self, name: str) -> None:
        if name == self.default_branch:
            raise CatalogError("refusing to delete the default branch")
        self.store.delete_ref(_BRANCH_NS, name)

    def has_branch(self, name: str) -> bool:
        return self.store.get_ref(_BRANCH_NS, name) is not None

    # -------------------------------------------------------------- writing
    def commit(
        self,
        branch: str,
        updates: Dict[str, Optional[str]],
        *,
        message: str = "",
        author: str = "user",
        expect: Optional[Dict[str, Optional[str]]] = None,
    ) -> Commit:
        """Commit table updates to a branch (``None`` value deletes a table).

        Uses CAS on the branch head: concurrent commits retry against the
        fresh head, so a lost-update can't happen (optimistic concurrency).

        ``expect`` maps table name -> the snapshot key the caller derived
        its update *from*; if the fresh head disagrees, ``MergeConflict``
        is raised instead of silently overwriting a concurrent change.
        Derived rewrites (e.g. compaction) need this: their update is only
        valid against the exact version they read.
        """
        for _ in range(64):
            ref = self.store.get_ref(_BRANCH_NS, branch)
            if ref is None:
                raise CatalogError(f"no such branch {branch!r}")
            head = self.get_commit(ref["commit"])
            if expect is not None:
                for name, key in expect.items():
                    if head.tables.get(name) != key:
                        raise MergeConflict(
                            f"table {name!r} changed concurrently on "
                            f"{branch!r} (expected {key!r})"
                        )
            tables = dict(head.tables)
            for name, key in updates.items():
                if key is None:
                    tables.pop(name, None)
                else:
                    tables[name] = key
            commit = self._write_commit(
                parent_id=head.commit_id, tables=tables, message=message, author=author
            )
            if self.store.compare_and_set_ref(
                _BRANCH_NS, branch, ref, {"commit": commit.commit_id}
            ):
                return commit
        raise CatalogError(f"commit contention on branch {branch!r}")

    # -------------------------------------------------------------- reading
    def table_key(self, name: str, *, branch: Optional[str] = None,
                  commit_id: Optional[str] = None) -> str:
        """Resolve a logical table name to a snapshot manifest key.

        ``commit_id`` gives time travel to any historical commit.
        """
        commit = (
            self.get_commit(commit_id)
            if commit_id is not None
            else self.head(branch or self.default_branch)
        )
        if name not in commit.tables:
            where = commit_id or branch or self.default_branch
            raise CatalogError(f"table {name!r} not found at {where!r}")
        return commit.tables[name]

    def tables(self, *, branch: Optional[str] = None) -> Dict[str, str]:
        return dict(self.head(branch or self.default_branch).tables)

    def log(self, branch: str, *, limit: int = 50) -> List[Commit]:
        out, cur = [], self.head(branch)
        while cur is not None and len(out) < limit:
            out.append(cur)
            # stop at the history horizon (parent expired by gc)
            cur = self.get_commit_opt(cur.parent_id)
        return out

    # -------------------------------------------------------------- merging
    def _ancestors(self, commit_id: str) -> List[str]:
        """Commit ids reachable from ``commit_id``, horizon-tolerant."""
        seen: List[str] = []
        stack = [commit_id]
        while stack:
            cid = stack.pop()
            if cid in seen:
                continue
            c = self.get_commit_opt(cid)
            if c is None:  # expired by gc — history ends here
                continue
            seen.append(cid)
            if c.parent_id:
                stack.append(c.parent_id)
            if c.extra_parent_id:
                stack.append(c.extra_parent_id)
        return seen

    def merge_base(self, a: str, b: str) -> Optional[str]:
        ancestors_a = self._ancestors(a)
        set_a = set(ancestors_a)
        # BFS from b in order — first hit is the nearest common ancestor.
        stack = [b]
        visited = set()
        while stack:
            cid = stack.pop(0)
            if cid in set_a:
                return cid
            if cid in visited:
                continue
            visited.add(cid)
            c = self.get_commit_opt(cid)
            if c is None:  # beyond the gc horizon: no ancestry there
                continue
            if c.parent_id:
                stack.append(c.parent_id)
            if c.extra_parent_id:
                stack.append(c.extra_parent_id)
        return None

    # --------------------------------------------------------- reachability
    def reachable_commits(
        self,
        *,
        extra_roots: Sequence[str] = (),
        history: Optional[int] = None,
    ) -> Dict[str, Commit]:
        """Enumerate commits reachable from every branch head, every tag
        and ``extra_roots`` — the mark phase's catalog walk.

        ``history`` bounds the walk depth from each *branch head* (None =
        unlimited): ``history=1`` keeps only the heads themselves,
        Iceberg-style snapshot expiry.  Tag and extra roots are always
        kept but their ancestry honours the same bound, counted from the
        root.  Merge parents (``extra_parent_id``) count as one step like
        first parents.
        """
        if history is not None and history < 1:
            # history=0 would mark NOTHING live — a sweep against that
            # live set destroys every branch head's data
            raise ValueError(f"history must be >= 1, got {history}")
        roots: List[str] = []
        for branch in self.branches():
            ref = self.store.get_ref(_BRANCH_NS, branch)
            if ref is not None:
                roots.append(ref["commit"])
        roots.extend(self.tags().values())
        roots.extend(extra_roots)

        out: Dict[str, Commit] = {}
        if history is None:
            # unbounded: a plain visited-set walk — shared ancestry is
            # traversed once regardless of how many roots reach it
            stack = list(roots)
            while stack:
                cid = stack.pop()
                if cid in out:
                    continue
                c = self.get_commit_opt(cid)
                if c is None:
                    continue  # dangling root or expired parent
                out[cid] = c
                if c.parent_id:
                    stack.append(c.parent_id)
                if c.extra_parent_id:
                    stack.append(c.extra_parent_id)
            return out

        # depth-bounded: a commit must be re-expanded when another root
        # reaches it shallower (its ancestry extends further down)
        best_depth: Dict[str, int] = {}
        dstack: List[tuple] = [(cid, 1) for cid in roots]
        while dstack:
            cid, depth = dstack.pop()
            if depth > history:
                continue
            if best_depth.get(cid, 1 << 60) <= depth:
                continue  # already visited at least this shallowly
            c = self.get_commit_opt(cid)
            if c is None:
                continue  # dangling root or expired parent
            best_depth[cid] = depth
            out[cid] = c
            if c.parent_id:
                dstack.append((c.parent_id, depth + 1))
            if c.extra_parent_id:
                dstack.append((c.extra_parent_id, depth + 1))
        return out

    def merge(
        self,
        source: str,
        target: str,
        *,
        message: str = "",
        author: str = "user",
        delete_source: bool = False,
    ) -> Commit:
        """Three-way merge of branch ``source`` into branch ``target``.

        Table-level granularity (a table is the merge unit, like Nessie's
        content keys): if both sides changed the same table since the merge
        base, raise ``MergeConflict`` — the paper's runner avoids this by
        construction because ephemeral branches merge back immediately.
        """
        for _ in range(64):
            src_head = self.head(source)
            tgt_ref = self.store.get_ref(_BRANCH_NS, target)
            if tgt_ref is None:
                raise CatalogError(f"no such branch {target!r}")
            tgt_head = self.get_commit(tgt_ref["commit"])
            base_id = self.merge_base(src_head.commit_id, tgt_head.commit_id)
            base_tables = self.get_commit(base_id).tables if base_id else {}
            merged = dict(tgt_head.tables)
            for name in set(src_head.tables) | set(base_tables):
                src_val = src_head.tables.get(name)
                tgt_val = tgt_head.tables.get(name)
                base_val = base_tables.get(name)
                if src_val == base_val:
                    continue  # source didn't touch it
                if tgt_val != base_val and tgt_val != src_val:
                    raise MergeConflict(
                        f"table {name!r} changed on both {source!r} and {target!r}"
                    )
                if src_val is None:
                    merged.pop(name, None)
                else:
                    merged[name] = src_val
            commit = self._write_commit(
                parent_id=tgt_head.commit_id,
                tables=merged,
                message=message or f"merge {source} into {target}",
                author=author,
                extra_parent_id=src_head.commit_id,
            )
            if self.store.compare_and_set_ref(
                _BRANCH_NS, target, tgt_ref, {"commit": commit.commit_id}
            ):
                if delete_source:
                    self.delete_branch(source)
                return commit
        raise CatalogError(f"merge contention on branch {target!r}")

    # ----------------------------------------------------------------- tags
    def tag(self, name: str, commit_id: str) -> None:
        self.store.set_ref(_TAG_NS, name, {"commit": commit_id})

    def tags(self) -> Dict[str, str]:
        """All tags: name -> commit id."""
        return {
            name: ref["commit"]
            for name, ref in self.store.list_refs(_TAG_NS).items()
        }

    def resolve_tag(self, name: str) -> str:
        ref = self.store.get_ref(_TAG_NS, name)
        if ref is None:
            raise CatalogError(f"no such tag {name!r}")
        return ref["commit"]
