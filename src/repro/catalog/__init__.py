"""Versioned data catalog — the Nessie-like layer (paper 4.3, Fig. 4).

The catalog versions *the whole namespace at once*: a commit maps every
table (and model artifact) name to an immutable snapshot manifest key.
Branches are mutable refs onto the commit DAG; runs execute in ephemeral
branches and merge atomically (transform-audit-write).
"""
from repro.catalog.nessie import Catalog, Commit, CatalogError, MergeConflict

__all__ = ["Catalog", "Commit", "CatalogError", "MergeConflict"]
