"""Storage layer: content-addressed object store + tensor serialization.

This is the "data lake" of the lakehouse (paper Fig. 2, bottom): raw files
live in object storage; every higher layer (table format, catalog,
checkpoints, run snapshots) addresses immutable blobs through this store.
"""
from repro.io.objectstore import ObjectStore, StoreStats
from repro.io.serialization import (
    array_to_bytes,
    bytes_to_array,
    dumps_json,
    loads_json,
)

__all__ = [
    "ObjectStore",
    "StoreStats",
    "array_to_bytes",
    "bytes_to_array",
    "dumps_json",
    "loads_json",
]
