"""Content-addressed object store — the local-filesystem stand-in for S3.

Design points lifted from the paper:

* **Immutability**: objects are keyed by content hash; a key never changes
  meaning.  This is what makes catalog branches, time travel and run replay
  (4.3, 4.4.1) trivially correct — a snapshot is just a set of keys.
* **Object storage as last resort** (4.5): the store counts puts/gets/bytes
  so the physical planner and benchmarks can *prove* fusion avoided
  spillover (the paper's 5x claim is about exactly this).
* Namespaced refs: small mutable pointers (branch heads) live in a separate
  ref space with atomic swap semantics, mirroring how Nessie keeps branch
  heads apart from immutable commits.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, Optional, Set

from repro.utils.hashing import content_hash


@dataclass
class StoreStats:
    """Telemetry: the 'bytes moved' ledger used by planner + benchmarks.

    Counter updates are atomic under the ledger's own lock (``bump``), so
    concurrently executing stages — the wave scheduler runs shard reads
    and artifact writes from many threads — can never lose I/O accounting,
    regardless of which component holds the ``ObjectStore`` lock.
    """

    puts: int = 0
    gets: int = 0
    bytes_written: int = 0
    bytes_read: int = 0
    ref_updates: int = 0
    #: differential cache: stages restored from the store instead of
    #: recomputed, and the output bytes that were NOT re-written as a result
    cache_hits: int = 0
    cache_bytes_saved: int = 0
    #: lakekeeper maintenance ledger (see repro.maintenance): the gc_*,
    #: cache_entries_* and compact_* counters are maintenance telemetry,
    #: not run I/O — the runner's per-run io delta excludes those prefixes
    gc_objects_swept: int = 0
    gc_bytes_reclaimed: int = 0
    cache_entries_evicted: int = 0
    compact_shards_merged: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        # duck-typed MetricsRegistry (attach_metrics) — kept out of the
        # dataclass fields so snapshot()/asdict semantics are unchanged
        self._metrics = None

    def attach_metrics(self, registry) -> None:
        """Mirror every future bump into ``store.<counter>`` counters on a
        :class:`repro.telemetry.metrics.MetricsRegistry` (duck-typed: any
        object with ``counter(name).inc(n)``).  The unified metrics plane
        absorbs this ledger without touching any bump call site."""
        with self._lock:
            self._metrics = registry

    def bump(self, **deltas: int) -> None:
        """Atomically increment counters by name — the single mutation
        path; every writer goes through here."""
        with self._lock:
            for name, delta in deltas.items():
                setattr(self, name, getattr(self, name) + delta)
            metrics = self._metrics
        if metrics is not None:
            for name, delta in deltas.items():
                metrics.counter(f"store.{name}").inc(delta)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "puts": self.puts,
                "gets": self.gets,
                "bytes_written": self.bytes_written,
                "bytes_read": self.bytes_read,
                "ref_updates": self.ref_updates,
                "cache_hits": self.cache_hits,
                "cache_bytes_saved": self.cache_bytes_saved,
                "gc_objects_swept": self.gc_objects_swept,
                "gc_bytes_reclaimed": self.gc_bytes_reclaimed,
                "cache_entries_evicted": self.cache_entries_evicted,
                "compact_shards_merged": self.compact_shards_merged,
            }


@dataclass(frozen=True)
class SweepResult:
    """Outcome of one mark-and-sweep pass over the blob space."""

    swept: int
    bytes_reclaimed: int
    #: unreachable objects spared because they are younger than the grace
    #: period (an in-flight run may have written them before committing)
    kept_young: int
    dry_run: bool


@dataclass
class ObjectStore:
    """A content-addressed blob store rooted at a local directory.

    Layout::

        root/
          objects/ab/cdef....        # immutable blobs, sharded by prefix
          refs/<namespace>/<name>    # small mutable pointers (JSON)
    """

    root: Path
    stats: StoreStats = field(default_factory=StoreStats)

    def __post_init__(self) -> None:
        self.root = Path(self.root)
        (self.root / "objects").mkdir(parents=True, exist_ok=True)
        (self.root / "refs").mkdir(parents=True, exist_ok=True)
        # RLock: compare_and_set_ref holds the lock across get_ref/set_ref
        # (stats counters have their own lock inside StoreStats).
        self._lock = threading.RLock()

    # ------------------------------------------------------------------ blobs
    def _object_path(self, key: str) -> Path:
        return self.root / "objects" / key[:2] / key[2:]

    def put(self, data: bytes) -> str:
        """Store a blob, return its content address. Idempotent."""
        key = content_hash(data)
        path = self._object_path(key)
        self.stats.bump(puts=1, bytes_written=len(data))
        if path.exists():  # content-addressed: already present...
            # ...but refresh its mtime: the GC grace period keys off object
            # age, and a writer deduping onto an old *unreachable* blob
            # must re-arm the grace window or a concurrent sweep could
            # delete the blob before this writer commits a reference to it
            try:
                os.utime(path, None)
                return key
            except FileNotFoundError:
                pass  # a concurrent sweep won the race — rewrite below
        path.parent.mkdir(parents=True, exist_ok=True)
        # Write-then-rename for atomicity (a crashed writer never leaves a
        # half-object visible — required for checkpoint fault tolerance).
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return key

    def get(self, key: str) -> bytes:
        path = self._object_path(key)
        data = path.read_bytes()
        actual = content_hash(data)
        if actual != key:
            raise IOError(f"object store corruption: key={key} hash={actual}")
        self.stats.bump(gets=1, bytes_read=len(data))
        return data

    def exists(self, key: str) -> bool:
        return self._object_path(key).exists()

    def record_cache_hit(self, bytes_saved: int) -> None:
        """Count a differential-cache restore: one stage skipped,
        ``bytes_saved`` output bytes NOT re-written to the store."""
        self.stats.bump(cache_hits=1, cache_bytes_saved=bytes_saved)

    def bump_stat(self, counter: str, n: int = 1) -> None:
        """Thread-safe increment of a StoreStats counter by name (the
        maintenance services report through this)."""
        self.stats.bump(**{counter: n})

    def keys(self) -> Iterator[str]:
        objects = self.root / "objects"
        for shard in sorted(objects.iterdir()):
            if shard.is_dir():
                for obj in sorted(shard.iterdir()):
                    if not obj.name.startswith(".tmp-"):
                        yield shard.name + obj.name

    def object_size(self, key: str) -> Optional[int]:
        """Size in bytes of a stored blob, or None if absent."""
        try:
            return self._object_path(key).stat().st_size
        except FileNotFoundError:
            return None

    def object_age_s(self, key: str, *, now: Optional[float] = None) -> Optional[float]:
        """Seconds since the blob was (last) written, or None if absent."""
        try:
            mtime = self._object_path(key).stat().st_mtime
        except FileNotFoundError:
            return None
        return max(0.0, (now if now is not None else time.time()) - mtime)

    def delete(self, key: str) -> int:
        """Delete a blob; return bytes freed (0 if already absent).

        Idempotent — deletion is a maintenance operation (GC sweep) that
        must be safely retryable after a crashed or concurrent sweeper.
        """
        path = self._object_path(key)
        try:
            size = path.stat().st_size
            path.unlink()
        except FileNotFoundError:
            return 0
        return size

    def sweep(
        self,
        live: Set[str],
        *,
        grace_s: float = 0.0,
        dry_run: bool = False,
        now: Optional[float] = None,
    ) -> SweepResult:
        """Delete every blob not in ``live`` (the sweep half of mark-and-sweep).

        ``grace_s`` spares unreachable objects younger than the grace
        period: an in-flight run writes stage outputs *before* committing
        them to its ephemeral branch, so a concurrent sweeper would see
        them as garbage for a moment.  ``dry_run`` reports what would be
        reclaimed without deleting anything.
        """
        now = now if now is not None else time.time()
        swept = 0
        bytes_reclaimed = 0
        kept_young = 0
        for key in list(self.keys()):
            if key in live:
                continue
            age = self.object_age_s(key, now=now)
            if age is None:
                continue  # raced with another sweeper
            if age < grace_s:
                kept_young += 1
                continue
            size = self.object_size(key) or 0
            if not dry_run:
                # re-check age at delete time: a writer deduping onto this
                # blob re-arms the grace window via put()'s utime, and the
                # first stat above may predate it (check-then-delete race)
                age = self.object_age_s(key, now=time.time())
                if age is None:
                    continue
                if age < grace_s:
                    kept_young += 1
                    continue
                size = self.delete(key)
            swept += 1
            bytes_reclaimed += size
        if not dry_run:
            self.stats.bump(
                gc_objects_swept=swept, gc_bytes_reclaimed=bytes_reclaimed
            )
        return SweepResult(swept, bytes_reclaimed, kept_young, dry_run)

    # ------------------------------------------------------------------- refs
    def _ref_path(self, namespace: str, name: str) -> Path:
        safe = name.replace("/", "__")
        return self.root / "refs" / namespace / safe

    def set_ref(self, namespace: str, name: str, value: Dict) -> None:
        path = self._ref_path(namespace, name)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=".tmp-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(value, f, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.stats.bump(ref_updates=1)

    def get_ref(self, namespace: str, name: str) -> Optional[Dict]:
        path = self._ref_path(namespace, name)
        if not path.exists():
            return None
        return json.loads(path.read_text())

    def delete_ref(self, namespace: str, name: str) -> bool:
        """Delete a ref; return whether it existed.

        Idempotent (no-op on a missing ref, even under a concurrent
        deleter) so eviction and GC sweeps can retry safely.
        """
        path = self._ref_path(namespace, name)
        try:
            path.unlink()
        except FileNotFoundError:
            return False
        return True

    def list_refs(self, namespace: str) -> Dict[str, Dict]:
        ns = self.root / "refs" / namespace
        if not ns.exists():
            return {}
        out = {}
        for p in sorted(ns.iterdir()):
            if p.is_file() and not p.name.startswith(".tmp-"):
                out[p.name.replace("__", "/")] = json.loads(p.read_text())
        return out

    def compare_and_set_ref(
        self, namespace: str, name: str, expected: Optional[Dict], value: Dict
    ) -> bool:
        """Atomic CAS on a ref — the primitive behind safe branch updates."""
        with self._lock:
            current = self.get_ref(namespace, name)
            if current != expected:
                return False
            self.set_ref(namespace, name, value)
            return True
