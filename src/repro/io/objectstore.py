"""Content-addressed object store — the local-filesystem stand-in for S3.

Design points lifted from the paper:

* **Immutability**: objects are keyed by content hash; a key never changes
  meaning.  This is what makes catalog branches, time travel and run replay
  (4.3, 4.4.1) trivially correct — a snapshot is just a set of keys.
* **Object storage as last resort** (4.5): the store counts puts/gets/bytes
  so the physical planner and benchmarks can *prove* fusion avoided
  spillover (the paper's 5x claim is about exactly this).
* Namespaced refs: small mutable pointers (branch heads) live in a separate
  ref space with atomic swap semantics, mirroring how Nessie keeps branch
  heads apart from immutable commits.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, Optional

from repro.utils.hashing import content_hash


@dataclass
class StoreStats:
    """Telemetry: the 'bytes moved' ledger used by planner + benchmarks."""

    puts: int = 0
    gets: int = 0
    bytes_written: int = 0
    bytes_read: int = 0
    ref_updates: int = 0
    #: differential cache: stages restored from the store instead of
    #: recomputed, and the output bytes that were NOT re-written as a result
    cache_hits: int = 0
    cache_bytes_saved: int = 0

    def snapshot(self) -> Dict[str, int]:
        return {
            "puts": self.puts,
            "gets": self.gets,
            "bytes_written": self.bytes_written,
            "bytes_read": self.bytes_read,
            "ref_updates": self.ref_updates,
            "cache_hits": self.cache_hits,
            "cache_bytes_saved": self.cache_bytes_saved,
        }


@dataclass
class ObjectStore:
    """A content-addressed blob store rooted at a local directory.

    Layout::

        root/
          objects/ab/cdef....        # immutable blobs, sharded by prefix
          refs/<namespace>/<name>    # small mutable pointers (JSON)
    """

    root: Path
    stats: StoreStats = field(default_factory=StoreStats)

    def __post_init__(self) -> None:
        self.root = Path(self.root)
        (self.root / "objects").mkdir(parents=True, exist_ok=True)
        (self.root / "refs").mkdir(parents=True, exist_ok=True)
        # RLock: compare_and_set_ref holds the lock across get_ref/set_ref,
        # and set_ref bumps stats under the same lock.
        self._lock = threading.RLock()

    # ------------------------------------------------------------------ blobs
    def _object_path(self, key: str) -> Path:
        return self.root / "objects" / key[:2] / key[2:]

    def put(self, data: bytes) -> str:
        """Store a blob, return its content address. Idempotent."""
        key = content_hash(data)
        path = self._object_path(key)
        with self._lock:
            self.stats.puts += 1
            self.stats.bytes_written += len(data)
        if path.exists():  # content-addressed: already present, done.
            return key
        path.parent.mkdir(parents=True, exist_ok=True)
        # Write-then-rename for atomicity (a crashed writer never leaves a
        # half-object visible — required for checkpoint fault tolerance).
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return key

    def get(self, key: str) -> bytes:
        path = self._object_path(key)
        data = path.read_bytes()
        actual = content_hash(data)
        if actual != key:
            raise IOError(f"object store corruption: key={key} hash={actual}")
        with self._lock:
            self.stats.gets += 1
            self.stats.bytes_read += len(data)
        return data

    def exists(self, key: str) -> bool:
        return self._object_path(key).exists()

    def record_cache_hit(self, bytes_saved: int) -> None:
        """Count a differential-cache restore: one stage skipped,
        ``bytes_saved`` output bytes NOT re-written to the store."""
        with self._lock:
            self.stats.cache_hits += 1
            self.stats.cache_bytes_saved += bytes_saved

    def keys(self) -> Iterator[str]:
        objects = self.root / "objects"
        for shard in sorted(objects.iterdir()):
            if shard.is_dir():
                for obj in sorted(shard.iterdir()):
                    yield shard.name + obj.name

    # ------------------------------------------------------------------- refs
    def _ref_path(self, namespace: str, name: str) -> Path:
        safe = name.replace("/", "__")
        return self.root / "refs" / namespace / safe

    def set_ref(self, namespace: str, name: str, value: Dict) -> None:
        path = self._ref_path(namespace, name)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), prefix=".tmp-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(value, f, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        with self._lock:
            self.stats.ref_updates += 1

    def get_ref(self, namespace: str, name: str) -> Optional[Dict]:
        path = self._ref_path(namespace, name)
        if not path.exists():
            return None
        return json.loads(path.read_text())

    def delete_ref(self, namespace: str, name: str) -> None:
        path = self._ref_path(namespace, name)
        if path.exists():
            path.unlink()

    def list_refs(self, namespace: str) -> Dict[str, Dict]:
        ns = self.root / "refs" / namespace
        if not ns.exists():
            return {}
        out = {}
        for p in sorted(ns.iterdir()):
            if p.is_file() and not p.name.startswith(".tmp-"):
                out[p.name.replace("__", "/")] = json.loads(p.read_text())
        return out

    def compare_and_set_ref(
        self, namespace: str, name: str, expected: Optional[Dict], value: Dict
    ) -> bool:
        """Atomic CAS on a ref — the primitive behind safe branch updates."""
        with self._lock:
            current = self.get_ref(namespace, name)
            if current != expected:
                return False
            self.set_ref(namespace, name, value)
            return True
