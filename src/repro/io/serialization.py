"""Tensor/manifest (de)serialization.

We use a small self-describing binary framing (the 'parquet of spare parts'):
an 8-byte magic + JSON header (dtype/shape) + raw C-contiguous bytes.  It is
deliberately simple — the table format layers column statistics and shard
manifests on top (table/format.py), mirroring how Parquet + Iceberg split
responsibilities.
"""
from __future__ import annotations

import json
from typing import Any, Dict

import numpy as np

_MAGIC = b"RPRTNSR1"


def array_to_bytes(arr: np.ndarray) -> bytes:
    shape = list(np.shape(arr))  # BEFORE ascontiguousarray (it 1-d-ifies 0-d)
    arr = np.ascontiguousarray(arr)
    header = json.dumps({"dtype": str(arr.dtype), "shape": shape}).encode()
    return _MAGIC + len(header).to_bytes(4, "little") + header + arr.tobytes()


def bytes_to_array(data: bytes) -> np.ndarray:
    if data[:8] != _MAGIC:
        raise ValueError("not a repro tensor blob")
    hlen = int.from_bytes(data[8:12], "little")
    header = json.loads(data[12 : 12 + hlen].decode())
    raw = data[12 + hlen :]
    arr = np.frombuffer(raw, dtype=np.dtype(header["dtype"]))
    return arr.reshape(header["shape"]).copy()


def dumps_json(obj: Dict[str, Any]) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def loads_json(data: bytes) -> Dict[str, Any]:
    return json.loads(data.decode())
