"""``repro.Client`` — the one construction path onto the platform.

The paper's pitch (4.1, 4.6) is that the entire lakehouse hides behind a
single Python client: no ``ObjectStore → Catalog → TableFormat →
ServerlessExecutor → Runner`` constructor soup in user code.  The Client
owns that wiring and exposes every surface on one object:

* data:        ``write_table / query / tables / log / tag``
* branches:    ``branch("feat_1")`` → a ``BranchHandle`` context manager
  (ephemeral by default — merge on success, roll back on audit failure)
* pipelines:   ``run / replay`` returning a typed ``RunHandle``, and
  ``run_async`` returning a future-like ``AsyncRunHandle``
* maintenance: ``gc() / compact() / cache.stats() / cache.prune()``

``Runner`` remains importable from ``repro.core`` as the internal engine;
``repro.Runner`` is a deprecation shim pointing here.

On open the Client also loads the executor's per-fingerprint speculation
latency history from the lake (``latencyhist`` namespace) and persists it
back after every run — a fresh process inherits straggler baselines
instead of re-learning them (ROADMAP item, closed).
"""
from __future__ import annotations

import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from types import ModuleType
from typing import Any, Dict, List, Optional, Union

import numpy as np

from repro.analysis import LintFailed, LintReport, lint_pipeline
from repro.api.handles import AsyncRunHandle, RunHandle, RunState
from repro.api.project import Project, resolve_pipeline
from repro.catalog.nessie import Catalog, Commit
from repro.core.physical import PlannerConfig
from repro.core.pipeline import Pipeline
from repro.core.runner import ExpectationFailed, Runner, RunResult
from repro.core.snapshot import NodeCacheRegistry
from repro.io.objectstore import ObjectStore
from repro.maintenance import (
    CompactionReport,
    EvictionPolicy,
    EvictionReport,
    GCReport,
    collect_garbage,
    compact_branch,
    compact_table,
    prune_cache,
)
from repro.runtime.executor import ExecutorConfig, ServerlessExecutor
from repro.table.format import Snapshot, TableFormat
from repro.table.schema import Schema
from repro.telemetry.bus import EventBus, Subscription, read_spool
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.runlog import RunLogStore
from repro.telemetry.tracing import RunTrace
from repro.utils.logging import get_logger

log = get_logger("api.client")

#: spool file (JSON lines) the bus mirrors events into, relative to the
#: lake root — what a *separate* ``repro events --follow`` process tails
SPOOL_RELPATH = Path("telemetry") / "events.jsonl"

#: lake namespace persisting the executor's per-fingerprint latency
#: history (straggler-speculation baselines survive process restarts)
_LATENCY_NS = "latencyhist"

RunTarget = Union[Pipeline, Project, str, Path, ModuleType]


class CacheMaintenance:
    """``client.cache`` — the differential cache's maintenance face."""

    def __init__(self, client: "Client"):
        self._client = client

    @property
    def registry(self) -> NodeCacheRegistry:
        # the registry is stateless over the store, so maintenance verbs
        # must not force an executor/runner into existence to reach it
        return self._client.cache_registry

    def stats(self) -> Dict[str, Any]:
        """Registry size + entry listing (what ``repro cache stats`` prints)."""
        items = self.registry.entries()
        return {
            "entries": len(items),
            "total_bytes": sum(e.output_bytes for e in items.values()),
            "items": items,
        }

    def prune(
        self,
        *,
        max_bytes: Optional[int] = None,
        ttl_s: Optional[float] = None,
        dry_run: bool = False,
    ) -> EvictionReport:
        """Evict entries by LRU within a byte budget and/or TTL."""
        return prune_cache(
            self.registry,
            EvictionPolicy(max_bytes=max_bytes, ttl_s=ttl_s),
            dry_run=dry_run,
        )


class Client:
    """One object, the whole platform.  ``Client(path)`` opens (or
    initializes) a lake at ``path``; ``Client.ephemeral()`` gives a
    throwaway tempdir lake for examples/tests/benchmarks."""

    def __init__(
        self,
        path: Union[str, Path, None] = None,
        *,
        shard_rows: Optional[int] = None,
        executor_config: Optional[ExecutorConfig] = None,
        executor: Optional[ServerlessExecutor] = None,
        telemetry: bool = True,
    ):
        if path is None:
            path = tempfile.mkdtemp(prefix="repro_lake_")
        self.path = Path(path)
        self.store = ObjectStore(self.path)
        #: the observability plane: one bus every component publishes
        #: into, one metrics registry absorbing StoreStats/executor
        #: numbers, one runlog reading traces back.  ``telemetry=False``
        #: turns the bus off entirely (no events, no spool, no run log) —
        #: the benchmark baseline
        self.metrics = MetricsRegistry()
        self.bus: Optional[EventBus] = (
            EventBus(spool_path=self.path / SPOOL_RELPATH)
            if telemetry
            else None
        )
        self.runlog = RunLogStore(self.store)
        if telemetry:
            self.store.stats.attach_metrics(self.metrics)
        self.catalog = Catalog(self.store)
        self.fmt = (
            TableFormat(self.store, shard_rows=shard_rows)
            if shard_rows is not None
            else TableFormat(self.store)
        )
        self._executor_config = executor_config
        self._executor = executor
        self._owns_executor = executor is None
        self._runner: Optional[Runner] = None
        self.cache_registry = NodeCacheRegistry(self.store)
        self._closed = False
        #: guards lazy executor/runner construction — two concurrent
        #: run_async calls on a fresh Client must not build two fleets
        self._init_lock = threading.Lock()
        #: background lane for run_async (lazily created, joined on close);
        #: ``_closed`` is read/written under ``_async_lock`` so a racing
        #: run_async cannot recreate the pool after close() joined it
        self._async_pool: Optional[ThreadPoolExecutor] = None
        self._async_lock = threading.Lock()
        #: last-persisted latency histories (skip unchanged refs on save);
        #: guarded by ``_history_lock`` — concurrent async runs save too
        self._history_lock = threading.Lock()
        self._persisted_history: Dict[str, tuple] = {}
        self._persisted_forecasts: Dict[str, Dict[str, float]] = {}
        if executor is not None:
            self._load_latency_history()
        self.cache = CacheMaintenance(self)

    @classmethod
    def ephemeral(cls, **kwargs: Any) -> "Client":
        """A lake in a fresh temp directory (examples and tests)."""
        return cls(None, **kwargs)

    # ---------------------------------------------------------- lifecycle
    @property
    def executor(self) -> ServerlessExecutor:
        with self._init_lock:
            if self._executor is None:
                self._executor = ServerlessExecutor(
                    self._executor_config,
                    bus=self.bus, metrics=self.metrics,
                )
                self._load_latency_history()
            elif self._executor.bus is None and self.bus is not None:
                # caller-supplied fleet: adopt this lake's telemetry plane
                self._executor.bus = self.bus
                self._executor.metrics = self.metrics
            return self._executor

    @property
    def runner(self) -> Runner:
        """The internal engine (transform-audit-write orchestrator)."""
        executor = self.executor
        with self._init_lock:
            if self._runner is None:
                self._runner = Runner(
                    self.catalog, self.fmt, executor,
                    cache_registry=self.cache_registry,
                    bus=self.bus, runlog=self.runlog,
                )
            return self._runner

    def close(self) -> None:
        with self._async_lock:
            if self._closed:
                return
            self._closed = True
            pool, self._async_pool = self._async_pool, None
        if pool is not None:
            # join in-flight async runs BEFORE tearing the executor down —
            # a run mid-flight must never lose its container fleet
            pool.shutdown(wait=True)
        if self._executor is not None:
            self._save_latency_history()
            if self._owns_executor:
                self._executor.shutdown()
        if self.bus is not None:
            self.bus.close()

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def __repr__(self) -> str:
        return f"Client({str(self.path)!r})"

    # ------------------------------------------------- latency persistence
    def _load_latency_history(self) -> None:
        """Seed the executor's speculation baselines from the lake."""
        assert self._executor is not None
        refs = self.store.list_refs(_LATENCY_NS)
        history = {
            fp: [float(d) for d in raw.get("durations", [])]
            for fp, raw in refs.items()
        }
        if history:
            self._executor.seed_latency_history(history)
            log.info(
                "loaded latency baselines for %d function fingerprint(s)",
                len(history),
            )
        # keep persisted forecasts so an unchanged fingerprint's ref is
        # neither rewritten nor stripped of its forecast on save
        self._persisted_forecasts = {
            fp: dict(raw["forecast"])
            for fp, raw in refs.items()
            if isinstance(raw.get("forecast"), dict)
        }
        self._persisted_history = {
            fp: (
                tuple(ds),
                tuple(sorted(self._persisted_forecasts.get(fp, {}).items())),
            )
            for fp, ds in history.items()
        }

    def _save_latency_history(self) -> None:
        """Persist changed histories (tiny JSON refs, one per fingerprint).

        The scheduler's latest predicted-vs-actual forecast rides the same
        ref (``forecast`` key), so it ages out with the durations under the
        lakekeeper's ``latency_ttl_s`` sweep — no second GC policy.
        """
        if self._executor is None:
            return
        with self._history_lock:
            fresh = self._executor.forecasts()
            for fp, durations in self._executor.latency_history().items():
                # latest forecast wins; fall back to the persisted one so a
                # save without a new run never strips it from the ref
                forecast = fresh.get(fp) or self._persisted_forecasts.get(fp)
                snap = (
                    tuple(durations),
                    tuple(sorted((forecast or {}).items())),
                )
                if self._persisted_history.get(fp) == snap:
                    continue
                ref = {"durations": list(durations), "updated_at": time.time()}
                if forecast:
                    ref["forecast"] = dict(forecast)
                self.store.set_ref(_LATENCY_NS, fp, ref)
                self._persisted_history[fp] = snap
                if forecast:
                    self._persisted_forecasts[fp] = dict(forecast)

    # ------------------------------------------------------------ branches
    def branch(
        self,
        name: str,
        *,
        base: str = "main",
        ephemeral: Optional[bool] = None,
    ) -> "BranchHandle":
        """A branch-scoped view of the platform (context manager).

        ``ephemeral=None`` (default) resolves to True when the handle has
        to create the branch: on a clean ``with`` exit the branch merges
        into ``base`` and disappears; an exception or a non-SUCCESS run
        rolls it back instead (delete, no merge).  A pre-existing branch
        defaults to non-ephemeral — the handle scopes, the exit touches
        nothing.
        """
        return BranchHandle(self, name, base=base, ephemeral=ephemeral)

    def branches(self) -> List[str]:
        return self.catalog.branches()

    def create_branch(
        self, name: str, *, from_branch: Optional[str] = None
    ) -> Commit:
        return self.catalog.create_branch(name, from_branch=from_branch)

    def log(self, branch: str = "main", *, limit: int = 50) -> List[Commit]:
        return self.catalog.log(branch, limit=limit)

    def tables(self, branch: str = "main") -> Dict[str, str]:
        return self.catalog.tables(branch=branch)

    def tag(self, name: str, *, branch: str = "main",
            commit_id: Optional[str] = None) -> str:
        """Pin a name to a commit (GC root, time-travel anchor)."""
        target = commit_id or self.catalog.head(branch).commit_id
        self.catalog.tag(name, target)
        return target

    def tags(self) -> Dict[str, str]:
        return self.catalog.tags()

    # ---------------------------------------------------------------- data
    def write_table(
        self,
        name: str,
        data: Dict[str, np.ndarray],
        *,
        branch: str = "main",
        schema: Optional[Schema] = None,
        append: bool = False,
        message: Optional[str] = None,
        author: str = "user",
    ) -> Snapshot:
        """Write columnar data as a table version and commit it.

        The schema is inferred from the arrays unless given; ``append``
        extends the branch's current version via structural sharing.
        """
        if schema is None:
            schema = Schema.of(
                **{c: str(np.asarray(v).dtype) for c, v in data.items()}
            )
        parent: Optional[Snapshot] = None
        if append:
            head_tables = self.catalog.tables(branch=branch)
            if name in head_tables:
                parent = self.fmt.load_snapshot(head_tables[name])
        snap = self.fmt.write(
            name, schema, data, parent=parent, append=parent is not None
        )
        self.catalog.commit(
            branch,
            {name: self.fmt.manifest_key(snap)},
            message=message or f"write_table {name}",
            author=author,
        )
        return snap

    def query(
        self,
        sql: str,
        *,
        branch: Optional[str] = None,
        commit_id: Optional[str] = None,
        engine: str = "auto",
    ) -> Dict[str, np.ndarray]:
        """Synchronous SQL against a branch head or any commit.

        Zero registration: FROM/JOIN names resolve against the catalog at
        query time.  ``engine`` selects the filter+agg execution path —
        ``"auto"`` routes eligible plans through the fused Pallas kernel
        (exactness proven from shard stats, see ``repro.engine.route``),
        ``"kernel"`` forces it, ``"jnp"`` pins the reference path.
        """
        return self.runner.query(
            sql, branch=branch, commit_id=commit_id, engine=engine
        )

    # -------------------------------------------------------- observability
    def trace(self, run_id: int) -> RunTrace:
        """The persisted trace of a recorded run: span tree (run → stage →
        node/scan), queue-vs-exec-vs-commit breakdown, critical path,
        Chrome-trace export (``trace.write_chrome_trace(path)``).

        Raises ``KeyError`` when the run has no trace — telemetry was off,
        or ``gc --runlog-ttl`` expired it.
        """
        return RunTrace.from_events(self.runlog.get(run_id), run_id=run_id)

    def events(
        self,
        *,
        follow: bool = False,
        run_id: Optional[int] = None,
        buffer: int = 4096,
    ) -> Any:
        """The live event stream.

        ``follow=False`` (default) returns the events already mirrored to
        this lake's spool file — including those published by *other*
        processes.  ``follow=True`` returns a :class:`Subscription` on the
        in-process bus (context manager; ``poll()`` / ``follow()``), which
        sees everything published from now on.
        """
        if follow:
            if self.bus is None:
                raise RuntimeError(
                    "telemetry is disabled for this client "
                    "(Client(..., telemetry=True) to enable)"
                )
            return self.bus.subscribe(maxlen=buffer)
        return read_spool(self.path / SPOOL_RELPATH, run_id=run_id)

    # ---------------------------------------------------------------- lint
    def lint(
        self,
        target: RunTarget,
        *,
        branch: str = "main",
    ) -> LintReport:
        """Static preflight over a pipeline: lineage + schema checks,
        cache-poison rules, plan diagnostics, blast radius.

        Executes nothing and writes nothing — the only reads are catalog
        refs and table manifests, to resolve the schemas of external
        source tables at the ``branch`` head (falling back to ``main``
        when the branch does not exist yet).
        """
        pipeline = resolve_pipeline(target)
        schemas, snapshots, head = self._lint_inputs(pipeline, branch)
        return lint_pipeline(
            pipeline,
            external_schemas=schemas,
            external_snapshots=snapshots,
            catalog_tables=set(head),
        )

    def _lint_inputs(self, pipeline, branch: str):
        """Catalog-side inputs for the static passes: external-source
        schemas, loaded snapshots (shard stats for the typed checks), and
        the set of table names at the branch head.  Reads refs and
        manifests only — never shard data, never a write."""
        lookup = branch if self.catalog.has_branch(branch) else "main"
        head_tables = self.catalog.tables(branch=lookup)
        schemas: Dict[str, Optional[Schema]] = {}
        snapshots: Dict[str, Any] = {}
        for table in pipeline.external_sources():
            if table in head_tables:
                snap = self.fmt.load_snapshot(head_tables[table])
                snapshots[table] = snap
                schemas[table] = snap.schema
        return schemas, snapshots, head_tables

    def explain(
        self,
        target: Any,
        *,
        branch: str = "main",
        commit_id: Optional[str] = None,
        engine: str = "auto",
    ):
        """Static plan explainability — zero execution, zero store writes.

        Two modes, selected by the target:

        * a SQL string (``SELECT ...``) — returns an
          :class:`~repro.analysis.explain.ExplainedQuery`: planned scans,
          pushdown/pruning, the kernel-vs-jnp verdict with the full route
          trace (every eligibility check, pass/fail, fix hints), inferred
          output schema, and typed-dataflow findings.  The predicted
          ``engine_path`` — or the predicted :class:`RouteError` message,
          byte-for-byte — is exactly what ``client.query`` would do,
          because both read the same interactive plan.
        * a pipeline/project/module — returns a
          :class:`~repro.analysis.explain.PipelineExplanation`: per-node
          route verdicts (equal to what the physical planner stamps onto
          its stages) plus the full preflight :class:`LintReport`.
        """
        from repro.analysis.explain import explain_pipeline, explain_query

        if isinstance(target, str) and target.lstrip()[:6].lower() == "select":
            from repro.core.physical import resolve_query_snapshots
            from repro.engine.sql import parse_sql

            query = parse_sql(target)
            snapshots = resolve_query_snapshots(
                self.catalog, self.fmt, query,
                branch=branch, commit_id=commit_id, text=target,
            )
            return explain_query(query, snapshots, engine=engine)
        pipeline = resolve_pipeline(target)
        schemas, snapshots, head = self._lint_inputs(pipeline, branch)
        return explain_pipeline(
            pipeline,
            external_schemas=schemas,
            snapshots=snapshots,
            engine=engine,
            catalog_tables=set(head),
        )

    # ---------------------------------------------------------------- runs
    def run(
        self,
        target: RunTarget,
        *,
        branch: str = "main",
        params: Optional[Dict[str, Any]] = None,
        fusion: bool = True,
        pushdown: bool = True,
        cache: bool = True,
        base_commit: Optional[str] = None,
        author: str = "user",
        planner_config: Optional[PlannerConfig] = None,
        raise_errors: bool = True,
        parallelism: Optional[int] = None,
        preflight: bool = False,
        schedule: str = "critical_path",
        streaming: Optional[bool] = None,
    ) -> RunHandle:
        """Execute a pipeline/project/module with transform-audit-write.

        Always returns a ``RunHandle``; an audit failure is a typed
        ``AUDIT_FAILED`` outcome (run rolled back), never an exception.
        Infrastructure/user-code errors raise unless ``raise_errors=False``
        captures them into an ``ERROR`` handle.

        ``preflight=True`` lints the pipeline first (``Client.lint``) and
        refuses to launch on any error-severity finding — ``LintFailed``
        carries the full report (captured into an ``ERROR`` handle when
        ``raise_errors=False``).  Warnings never block a run.

        ``parallelism`` caps how many independent stages the wave
        scheduler keeps in flight (default: the executor config's
        ``max_concurrent_stages``, or the memory-capped admission gate
        under ``schedule="critical_path"``).  ``schedule`` picks the
        dispatch order — ``"critical_path"`` (cost-weighted longest path
        first, the default) or ``"stage_id"`` (ascending, the legacy
        wave order) — and ``streaming`` toggles the outputs-ready
        handoff plus incremental shard scans (default: on under
        critical_path, off under stage_id).  All three are throughput
        knobs only: results are byte-identical at every setting.
        """
        pipeline = resolve_pipeline(target)
        if preflight:
            report = self.lint(pipeline, branch=branch)
            if report.errors:
                err = LintFailed(report)
                if raise_errors:
                    raise err
                return RunHandle(
                    state=RunState.ERROR,
                    run_id=-1,
                    branch=branch,
                    merged_commit=None,
                    error=err,
                    _fmt=self.fmt,
                    _runlog=self.runlog,
                )
        try:
            result = self.runner.run(
                pipeline,
                branch=branch,
                params=params,
                fusion=fusion,
                pushdown=pushdown,
                cache=cache,
                base_commit=base_commit,
                author=author,
                planner_config=planner_config,
                parallelism=parallelism,
                schedule=schedule,
                streaming=streaming,
            )
        except ExpectationFailed as e:
            self._save_latency_history()
            rec = e.record
            return RunHandle(
                state=RunState.AUDIT_FAILED,
                run_id=rec.run_id if rec else -1,
                branch=branch,
                merged_commit=None,
                artifacts=dict(rec.artifacts) if rec else {},
                checks=dict(rec.checks) if rec else {},
                stats=dict(rec.stats) if rec else {},
                plan=e.plan,
                _fmt=self.fmt,
                _runlog=self.runlog,
            )
        except Exception as e:
            self._save_latency_history()
            if raise_errors:
                raise
            return RunHandle(
                state=RunState.ERROR,
                # the runner stamps its run id on escaping exceptions, so
                # the handle (and its trace) stay addressable; -1 only
                # when the failure predates run-id allocation
                run_id=getattr(e, "repro_run_id", -1),
                branch=branch,
                merged_commit=None,
                error=e,
                _fmt=self.fmt,
                _runlog=self.runlog,
            )
        self._save_latency_history()
        return self._handle_from_result(result)

    def run_async(
        self,
        target: RunTarget,
        *,
        branch: str = "main",
        params: Optional[Dict[str, Any]] = None,
        fusion: bool = True,
        pushdown: bool = True,
        cache: bool = True,
        base_commit: Optional[str] = None,
        author: str = "user",
        planner_config: Optional[PlannerConfig] = None,
        raise_errors: bool = False,
        parallelism: Optional[int] = None,
        preflight: bool = False,
        schedule: str = "critical_path",
        streaming: Optional[bool] = None,
    ) -> AsyncRunHandle:
        """``run()`` without the wait (paper Table 1's async runs).

        Submits the run to a background thread and returns immediately
        with a future-like ``AsyncRunHandle``: ``.state`` reads
        ``RUNNING`` until the run resolves, ``.poll()`` probes without
        blocking, ``.result()`` joins and yields the same typed
        ``RunHandle`` a synchronous ``run()`` would have returned —
        identical SUCCESS/AUDIT_FAILED/ERROR semantics, transform-audit-
        write included.  ``raise_errors`` defaults to **False** here so
        infrastructure errors resolve into an ``ERROR`` handle instead of
        detonating inside the background thread; pass ``True`` to have
        ``result()`` re-raise them.

        Concurrent async runs are safe — branch heads move via CAS, run
        ids are allocated atomically, and the executor fleet is shared —
        but per-run ``io`` deltas are store-global and may include a
        concurrent run's traffic.  ``close()`` joins in-flight runs.
        """
        # resolve on the caller's thread: module imports (and their
        # side-effectful project registration) don't belong on the lane
        pipeline = resolve_pipeline(target)
        with self._async_lock:
            # checked under the lock: a racing close() must not leave a
            # freshly-built pool (and a run against a dead fleet) behind
            if self._closed:
                raise RuntimeError("client is closed")
            if self._async_pool is None:
                self._async_pool = ThreadPoolExecutor(
                    max_workers=4, thread_name_prefix="run-async"
                )
            pool = self._async_pool
        future = pool.submit(
            self.run,
            pipeline,
            branch=branch,
            params=params,
            fusion=fusion,
            pushdown=pushdown,
            cache=cache,
            base_commit=base_commit,
            author=author,
            planner_config=planner_config,
            raise_errors=raise_errors,
            parallelism=parallelism,
            preflight=preflight,
            schedule=schedule,
            streaming=streaming,
        )
        return AsyncRunHandle(future, branch=branch)

    def replay(
        self,
        run_id: int,
        target: RunTarget,
        *,
        strict_code: bool = True,
    ) -> RunHandle:
        """Re-execute a recorded run: same code, same data version."""
        pipeline = resolve_pipeline(target)
        result = self.runner.replay(pipeline, run_id, strict_code=strict_code)
        self._save_latency_history()
        handle = self._handle_from_result(result, replay_of=run_id)
        return handle

    def _handle_from_result(
        self, result: RunResult, *, replay_of: Optional[int] = None
    ) -> RunHandle:
        # a merged run always audited clean, but replay re-executes WITHOUT
        # an audit gate (it never merges) — a reproduced failing check must
        # surface as AUDIT_FAILED, not ride a hardcoded SUCCESS
        ok = all(result.checks.values())
        return RunHandle(
            state=RunState.SUCCESS if ok else RunState.AUDIT_FAILED,
            run_id=result.run_id,
            branch=result.branch,
            merged_commit=result.merged_commit,
            artifacts=dict(result.artifacts),
            checks=dict(result.checks),
            stats=dict(result.stats),
            plan=result.plan,
            replay_of=replay_of,
            _fmt=self.fmt,
            _runlog=self.runlog,
        )

    # ---------------------------------------------------------- maintenance
    def gc(
        self,
        *,
        history: Optional[int] = None,
        grace_s: float = 900.0,
        pin_ttl_s: Optional[float] = 86400.0,
        latency_ttl_s: Optional[float] = 30 * 86400.0,
        runlog_ttl_s: Optional[float] = 14 * 86400.0,
        dry_run: bool = False,
    ) -> GCReport:
        """Mark-and-sweep unreachable objects (the lakekeeper's GC).

        ``runlog_ttl_s`` is the run-trace retention window: traces older
        than it are swept (ref + blob, one pass); None keeps every trace.
        """
        return collect_garbage(
            self.store, self.catalog, self.fmt,
            history=history, grace_s=grace_s,
            pin_ttl_s=pin_ttl_s, latency_ttl_s=latency_ttl_s,
            runlog_ttl_s=runlog_ttl_s,
            dry_run=dry_run, bus=self.bus,
        )

    def compact(
        self,
        table: Optional[str] = None,
        *,
        branch: str = "main",
        target_rows: Optional[int] = None,
        min_fill: float = 0.5,
        dry_run: bool = False,
    ) -> List[CompactionReport]:
        """Merge small shards into larger ones (one table or the branch)."""
        if table is not None:
            return [compact_table(
                self.catalog, self.fmt, table, branch=branch,
                target_rows=target_rows, min_fill=min_fill, dry_run=dry_run,
                bus=self.bus,
            )]
        return compact_branch(
            self.catalog, self.fmt, branch=branch,
            target_rows=target_rows, min_fill=min_fill, dry_run=dry_run,
            bus=self.bus,
        )


class BranchHandle:
    """A branch-scoped facade: the Client's surface with ``branch=`` fixed.

    As a context manager it gives the paper's feature-branch workflow the
    transactional shape of a run, one level up (Fig. 4): work lands on the
    branch; a clean exit merges it into ``base`` atomically and deletes
    the branch; an exception — or any run that did not SUCCEED — rolls
    the whole branch back instead.  Dirty artifacts never reach ``base``.
    """

    def __init__(
        self,
        client: Client,
        name: str,
        *,
        base: str = "main",
        ephemeral: Optional[bool] = None,
    ):
        self.client = client
        self.name = name
        self.base = base
        self._ephemeral = ephemeral
        self._created = False
        self._failed = False
        self._entered = False
        #: async runs launched through this handle — joined at exit so
        #: the merge/rollback decision never races an in-flight run
        self._async_handles: List[AsyncRunHandle] = []

    # ----------------------------------------------------------- lifecycle
    def _ensure(self) -> None:
        if not self.client.catalog.has_branch(self.name):
            self.client.catalog.create_branch(self.name, from_branch=self.base)
            self._created = True

    @property
    def ephemeral(self) -> bool:
        return self._created if self._ephemeral is None else self._ephemeral

    def __enter__(self) -> "BranchHandle":
        self._ensure()
        self._entered = True
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> None:
        self._entered = False
        # join in-flight async runs FIRST: the exit-time merge/rollback
        # decision must see their outcomes (and their merges into this
        # branch must not race its deletion).  The outcome is read off the
        # joined future directly — done-callbacks may still be in flight
        for handle in self._async_handles:
            try:
                ok = handle._future.result().ok
            except BaseException:
                ok = False  # an escaped infra error rolls the branch back
            if not ok:
                self._failed = True
        self._async_handles.clear()
        if not self.ephemeral:
            return
        if exc_type is not None or self._failed:
            # rollback: the branch (and everything only it referenced)
            # vanishes; base never sees it.  Blobs go at the next gc.
            self.client.catalog.delete_branch(self.name)
            log.info("rolled back ephemeral branch %r", self.name)
            return
        self.client.catalog.merge(
            self.name, self.base,
            message=f"merge branch {self.name}",
            delete_source=True,
        )
        log.info("merged ephemeral branch %r into %r", self.name, self.base)

    # ------------------------------------------------------- scoped surface
    def run(self, target: RunTarget, **kwargs: Any) -> RunHandle:
        self._ensure()
        kwargs.setdefault("raise_errors", False)
        handle = self.client.run(target, branch=self.name, **kwargs)
        if not handle.ok:
            self._failed = True
        return handle

    def run_async(self, target: RunTarget, **kwargs: Any) -> AsyncRunHandle:
        """Async run scoped to this branch.  Any handle still in flight
        when the ``with`` block exits is joined there, so the exit-time
        merge/rollback decision always sees the run's outcome."""
        self._ensure()
        handle = self.client.run_async(target, branch=self.name, **kwargs)

        def _note_outcome(fut: Any) -> None:
            try:
                ok = fut.result().ok
            except BaseException:
                ok = False
            if not ok:
                self._failed = True

        handle._future.add_done_callback(_note_outcome)
        self._async_handles.append(handle)
        return handle

    def lint(self, target: RunTarget) -> LintReport:
        """Preflight against this branch's table schemas."""
        self._ensure()
        return self.client.lint(target, branch=self.name)

    def explain(self, target: Any, **kwargs: Any) -> Any:
        """Static explain (SQL or pipeline) against this branch's head."""
        self._ensure()
        kwargs.setdefault("branch", self.name)
        return self.client.explain(target, **kwargs)

    def replay(self, run_id: int, target: RunTarget, **kwargs: Any) -> RunHandle:
        return self.client.replay(run_id, target, **kwargs)

    def query(self, sql: str, **kwargs: Any) -> Dict[str, np.ndarray]:
        self._ensure()
        kwargs.setdefault("branch", self.name)
        return self.client.query(sql, **kwargs)

    def write_table(self, name: str, data: Dict[str, np.ndarray],
                    **kwargs: Any) -> Snapshot:
        self._ensure()
        kwargs.setdefault("branch", self.name)
        return self.client.write_table(name, data, **kwargs)

    def tables(self) -> Dict[str, str]:
        self._ensure()
        return self.client.tables(branch=self.name)

    def log(self, **kwargs: Any) -> List[Commit]:
        self._ensure()
        return self.client.log(self.name, **kwargs)

    def tag(self, name: str, **kwargs: Any) -> str:
        self._ensure()
        kwargs.setdefault("branch", self.name)
        return self.client.tag(name, **kwargs)

    def head(self) -> Commit:
        self._ensure()
        return self.client.catalog.head(self.name)

    def __repr__(self) -> str:
        return (
            f"BranchHandle({self.name!r}, base={self.base!r}, "
            f"ephemeral={self.ephemeral})"
        )
