"""Typed run results — one handle, three states, lazy artifact reads.

``Client.run`` (and ``BranchHandle.run``) always hands back a
``RunHandle`` instead of the legacy mix of ``RunResult`` on success and
``ExpectationFailed`` raised on audit failure:

* ``SUCCESS``       — transform-audit-write completed, merged_commit set;
* ``AUDIT_FAILED``  — an expectation failed, the ephemeral branch was
  rolled back, nothing merged (a *domain outcome*, not an exception);
* ``ERROR``         — the run itself blew up (infrastructure/user code);
  raised by default, captured into a handle with ``raise_errors=False``.

``artifact(name)`` reads lazily through the table format — nothing is
deserialized until asked for.

``Client.run_async`` returns an ``AsyncRunHandle`` instead: a future-like
wrapper (``.state`` reads ``RUNNING`` until resolution, ``.poll()`` is
the non-blocking probe, ``.result()`` the blocking join) that resolves to
exactly the same typed ``RunHandle``.
"""
from __future__ import annotations

import concurrent.futures as cf
import enum
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.physical import PhysicalPlan
from repro.table.format import TableFormat


class RunState(str, enum.Enum):
    SUCCESS = "SUCCESS"
    AUDIT_FAILED = "AUDIT_FAILED"
    ERROR = "ERROR"
    #: an async run still executing (``AsyncRunHandle.state`` only —
    #: a resolved ``RunHandle`` is always one of the three final states)
    RUNNING = "RUNNING"

    def __str__(self) -> str:  # `print(handle.state)` reads cleanly
        return self.value


class RunFailed(RuntimeError):
    """Raised by ``RunHandle.raise_for_state()`` on a non-SUCCESS handle."""

    def __init__(self, handle: "RunHandle"):
        detail = (
            f"failed checks: {handle.failed_checks}"
            if handle.state is RunState.AUDIT_FAILED
            else repr(handle.error)
        )
        super().__init__(f"run {handle.run_id}: {handle.state} ({detail})")
        self.handle = handle


@dataclass
class RunHandle:
    """Everything a caller can ask about one run, success or not."""

    state: RunState
    run_id: int
    branch: str
    merged_commit: Optional[str]
    #: artifact name -> snapshot manifest key (content-addressed)
    artifacts: Dict[str, str] = field(default_factory=dict)
    checks: Dict[str, bool] = field(default_factory=dict)
    stats: Dict[str, Any] = field(default_factory=dict)
    plan: Optional[PhysicalPlan] = None
    #: set when this handle replays an earlier run (never merges)
    replay_of: Optional[int] = None
    #: the captured exception for ERROR handles
    error: Optional[BaseException] = None
    #: reader for lazy artifact access (bound by the Client)
    _fmt: Optional[TableFormat] = None
    #: run-log reader for trace() (bound by the Client when telemetry on)
    _runlog: Optional[Any] = None

    # ------------------------------------------------------------- status
    @property
    def ok(self) -> bool:
        return self.state is RunState.SUCCESS

    @property
    def failed_checks(self) -> List[str]:
        return sorted(k for k, v in self.checks.items() if not v)

    def raise_for_state(self) -> "RunHandle":
        """Raise ``RunFailed`` unless the run succeeded; chainable."""
        if self.state is not RunState.SUCCESS:
            if self.error is not None:
                raise RunFailed(self) from self.error
            raise RunFailed(self)
        return self

    # --------------------------------------------------------------- data
    @property
    def cache(self) -> Dict[str, Any]:
        """Node-level cache accounting (hits/rehydrated/elided/...)."""
        return dict(self.stats.get("cache", {}))

    @property
    def io(self) -> Dict[str, int]:
        """Object-store traffic this run moved (bytes/puts/gets deltas)."""
        return dict(self.stats.get("io", {}))

    def artifact(self, name: str) -> Dict[str, np.ndarray]:
        """Lazily read one produced artifact as columnar numpy arrays.

        Works for merged runs and replays; for an AUDIT_FAILED run the
        manifest keys still resolve until a GC sweep reclaims the rolled-
        back blobs (they are not rooted by any branch).
        """
        if name not in self.artifacts:
            raise KeyError(
                f"run {self.run_id} produced no artifact {name!r} "
                f"(have {sorted(self.artifacts)})"
            )
        if self._fmt is None:
            raise RuntimeError("handle is not bound to a table format")
        return self._fmt.read(self._fmt.load_snapshot(self.artifacts[name]))

    # ------------------------------------------------------- observability
    def trace(self) -> Any:
        """This run's :class:`repro.telemetry.tracing.RunTrace` — the
        span tree (run → stage → node/scan) assembled from the persisted
        run log, with queue/exec/commit breakdown, critical path and
        Chrome-trace export.  Works for every final state (a failed audit
        still records its trace).
        """
        if self._runlog is None:
            raise RuntimeError(
                "handle is not bound to a run log (telemetry disabled?)"
            )
        from repro.telemetry.tracing import RunTrace

        return RunTrace.from_events(
            self._runlog.get(self.run_id), run_id=self.run_id
        )

    def __repr__(self) -> str:
        merged = (
            self.merged_commit[:12] if self.merged_commit else None
        )
        return (
            f"RunHandle(run_id={self.run_id}, state={self.state}, "
            f"branch={self.branch!r}, merged={merged}, "
            f"artifacts={sorted(self.artifacts)})"
        )


class AsyncRunHandle:
    """Future-like handle for ``Client.run_async`` (paper Table 1).

    The run executes on a background thread; this handle wraps its
    future.  ``state`` is ``RunState.RUNNING`` until the run resolves,
    then the underlying ``RunHandle``'s state (``SUCCESS`` /
    ``AUDIT_FAILED`` / ``ERROR``) — same semantics as a synchronous run.
    ``poll()`` is the non-blocking probe (``None`` while running),
    ``result()`` the blocking join.
    """

    def __init__(self, future: "cf.Future[RunHandle]", *, branch: str):
        self._future = future
        self.branch = branch

    # ------------------------------------------------------------- status
    def done(self) -> bool:
        return self._future.done()

    @property
    def state(self) -> RunState:
        """Non-blocking: RUNNING until resolved, then the final state."""
        if not self._future.done():
            return RunState.RUNNING
        if self._future.exception() is not None:
            # run_async(raise_errors=True) let an infra error escape; the
            # exception itself surfaces on result()
            return RunState.ERROR
        return self._future.result().state

    @property
    def running(self) -> bool:
        return not self._future.done()

    # -------------------------------------------------------------- joins
    def poll(self) -> Optional[RunHandle]:
        """The resolved ``RunHandle``, or ``None`` while still running.
        Re-raises the run's exception if one escaped capture."""
        if not self._future.done():
            return None
        return self._future.result()

    def result(self, timeout: Optional[float] = None) -> RunHandle:
        """Block until the run resolves and return its ``RunHandle``
        (raises ``concurrent.futures.TimeoutError`` on timeout)."""
        return self._future.result(timeout)

    def raise_for_state(self) -> RunHandle:
        """Block, then raise ``RunFailed`` unless the run succeeded."""
        return self.result().raise_for_state()

    def trace(self) -> Any:
        """Block until resolved, then the run's trace (``RunHandle.trace``)."""
        return self.result().trace()

    def __repr__(self) -> str:
        if not self._future.done():
            return f"AsyncRunHandle(branch={self.branch!r}, state=RUNNING)"
        return f"AsyncRunHandle(resolved={self.poll()!r})"
