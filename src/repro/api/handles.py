"""Typed run results — one handle, three states, lazy artifact reads.

``Client.run`` (and ``BranchHandle.run``) always hands back a
``RunHandle`` instead of the legacy mix of ``RunResult`` on success and
``ExpectationFailed`` raised on audit failure:

* ``SUCCESS``       — transform-audit-write completed, merged_commit set;
* ``AUDIT_FAILED``  — an expectation failed, the ephemeral branch was
  rolled back, nothing merged (a *domain outcome*, not an exception);
* ``ERROR``         — the run itself blew up (infrastructure/user code);
  raised by default, captured into a handle with ``raise_errors=False``.

``artifact(name)`` reads lazily through the table format — nothing is
deserialized until asked for.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.physical import PhysicalPlan
from repro.table.format import TableFormat


class RunState(str, enum.Enum):
    SUCCESS = "SUCCESS"
    AUDIT_FAILED = "AUDIT_FAILED"
    ERROR = "ERROR"

    def __str__(self) -> str:  # `print(handle.state)` reads cleanly
        return self.value


class RunFailed(RuntimeError):
    """Raised by ``RunHandle.raise_for_state()`` on a non-SUCCESS handle."""

    def __init__(self, handle: "RunHandle"):
        detail = (
            f"failed checks: {handle.failed_checks}"
            if handle.state is RunState.AUDIT_FAILED
            else repr(handle.error)
        )
        super().__init__(f"run {handle.run_id}: {handle.state} ({detail})")
        self.handle = handle


@dataclass
class RunHandle:
    """Everything a caller can ask about one run, success or not."""

    state: RunState
    run_id: int
    branch: str
    merged_commit: Optional[str]
    #: artifact name -> snapshot manifest key (content-addressed)
    artifacts: Dict[str, str] = field(default_factory=dict)
    checks: Dict[str, bool] = field(default_factory=dict)
    stats: Dict[str, Any] = field(default_factory=dict)
    plan: Optional[PhysicalPlan] = None
    #: set when this handle replays an earlier run (never merges)
    replay_of: Optional[int] = None
    #: the captured exception for ERROR handles
    error: Optional[BaseException] = None
    #: reader for lazy artifact access (bound by the Client)
    _fmt: Optional[TableFormat] = None

    # ------------------------------------------------------------- status
    @property
    def ok(self) -> bool:
        return self.state is RunState.SUCCESS

    @property
    def failed_checks(self) -> List[str]:
        return sorted(k for k, v in self.checks.items() if not v)

    def raise_for_state(self) -> "RunHandle":
        """Raise ``RunFailed`` unless the run succeeded; chainable."""
        if self.state is not RunState.SUCCESS:
            if self.error is not None:
                raise RunFailed(self) from self.error
            raise RunFailed(self)
        return self

    # --------------------------------------------------------------- data
    @property
    def cache(self) -> Dict[str, Any]:
        """Node-level cache accounting (hits/rehydrated/elided/...)."""
        return dict(self.stats.get("cache", {}))

    @property
    def io(self) -> Dict[str, int]:
        """Object-store traffic this run moved (bytes/puts/gets deltas)."""
        return dict(self.stats.get("io", {}))

    def artifact(self, name: str) -> Dict[str, np.ndarray]:
        """Lazily read one produced artifact as columnar numpy arrays.

        Works for merged runs and replays; for an AUDIT_FAILED run the
        manifest keys still resolve until a GC sweep reclaims the rolled-
        back blobs (they are not rooted by any branch).
        """
        if name not in self.artifacts:
            raise KeyError(
                f"run {self.run_id} produced no artifact {name!r} "
                f"(have {sorted(self.artifacts)})"
            )
        if self._fmt is None:
            raise RuntimeError("handle is not bound to a table format")
        return self._fmt.read(self._fmt.load_snapshot(self.artifacts[name]))

    def __repr__(self) -> str:
        merged = (
            self.merged_commit[:12] if self.merged_commit else None
        )
        return (
            f"RunHandle(run_id={self.run_id}, state={self.state}, "
            f"branch={self.branch!r}, merged={merged}, "
            f"artifacts={sorted(self.artifacts)})"
        )
