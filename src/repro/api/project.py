"""Decorator-registered models — pipelines assembled by discovery.

Bauplan's SDK (paper 4.1) never asks the user to wire a DAG: functions
are declared with ``@bauplan.model()`` / ``@bauplan.expectation()`` and
the platform assembles the pipeline from what a module *defines*.  This
module reproduces that surface:

* ``@repro.model()``       — a Python artifact node (parents = argument
  names after ``ctx``, exactly like ``Pipeline.python``);
* ``@repro.expectation()`` — an audit node, whatever the function is
  called (no ``_expectation`` suffix needed);
* ``repro.sql("name", "SELECT ...")`` — a SQL artifact node;
* ``@repro.requirements({...})`` — pins packages into the fingerprint
  (re-exported from core unchanged).

Registrations land in a named ``Project``; the default project for a
registration is the defining module, so *importing a module yields its
DAG*: ``repro.discover("pipeline.py")`` / ``Client.run("pipeline.py")``.
Re-registering a name overwrites the previous definition (a module
re-imported or reloaded redefines, it does not collide) — ``Project``
is a mutable registry; an immutable ``Pipeline`` is minted per run.
"""
from __future__ import annotations

import importlib.util
import inspect
import sys
import threading
import warnings
from pathlib import Path
from types import ModuleType
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.pipeline import Node, Pipeline, PipelineError, requirements
from repro.engine.sql import parse_sql
from repro.utils.hashing import stable_hash

__all__ = [
    "Project",
    "RedefinitionWarning",
    "project",
    "model",
    "expectation",
    "sql",
    "requirements",
    "discover",
    "resolve_pipeline",
]


class RedefinitionWarning(UserWarning):
    """A node name was re-registered with *different* code.

    Re-importing the same module re-registers identical nodes silently
    (same fingerprint, nothing changed); this fires only when the new
    definition would quietly shadow a different one."""

#: global project registry — module-level decorators register here
_PROJECTS: Dict[str, "Project"] = {}
_LOCK = threading.Lock()


class Project:
    """A mutable, named registry of decorator-declared nodes.

    ``pipeline()`` mints an immutable ``Pipeline`` from the current
    registrations (insertion order preserved); the fingerprint machinery
    downstream is untouched — a Project is purely the assembly surface.
    """

    def __init__(self, name: str):
        self.name = name
        self._nodes: Dict[str, Node] = {}
        #: modules that registered nodes here (discovery bookkeeping)
        self.modules: set = set()
        #: node name -> (old location, new location) for names that were
        #: re-registered with DIFFERENT code; the linter reports these (G304)
        self.redefinitions: Dict[str, Tuple[str, str]] = {}

    # ------------------------------------------------------- registration
    def _register(self, node: Node, module: Optional[str]) -> None:
        if node.name in node.parents:
            raise PipelineError(f"node {node.name!r} references itself")
        old = self._nodes.get(node.name)
        if old is not None and old.fingerprint != node.fingerprint:
            old_loc = _loc_str(old)
            new_loc = _loc_str(node)
            self.redefinitions[node.name] = (old_loc, new_loc)
            warnings.warn(
                f"project {self.name!r}: node {node.name!r} redefined with "
                f"different code — {new_loc} replaces {old_loc}",
                RedefinitionWarning,
                stacklevel=3,
            )
        self._nodes[node.name] = node  # overwrite = redefinition
        if module:
            self.modules.add(module)

    def model(
        self,
        fn: Optional[Callable] = None,
        *,
        name: Optional[str] = None,
        materialize: bool = False,
    ) -> Callable:
        """Declare a Python artifact: parents are the args after ``ctx``."""

        def deco(f: Callable) -> Callable:
            node_name, parents = _fn_signature(f, name)
            self._register(
                Node(
                    name=node_name,
                    kind="python",
                    parents=parents,
                    fn=f,
                    requirements=getattr(f, "__repro_requirements__", {}),
                    materialize=materialize,
                    source_file=getattr(f.__code__, "co_filename", None),
                    source_line=getattr(f.__code__, "co_firstlineno", None),
                ),
                f.__module__,
            )
            return f

        return deco(fn) if fn is not None else deco

    def expectation(
        self, fn: Optional[Callable] = None, *, name: Optional[str] = None
    ) -> Callable:
        """Declare an audit node — any function name, no suffix required."""

        def deco(f: Callable) -> Callable:
            node_name, parents = _fn_signature(f, name)
            self._register(
                Node(
                    name=node_name,
                    kind="expectation",
                    parents=parents,
                    fn=f,
                    requirements=getattr(f, "__repro_requirements__", {}),
                    source_file=getattr(f.__code__, "co_filename", None),
                    source_line=getattr(f.__code__, "co_firstlineno", None),
                ),
                f.__module__,
            )
            return f

        return deco(fn) if fn is not None else deco

    def sql(
        self,
        name: str,
        sql_text: str,
        *,
        materialize: bool = False,
        _module: Optional[str] = None,
        _source: Optional[Tuple[Optional[str], Optional[int]]] = None,
    ) -> None:
        """Declare a SQL artifact; its parent is the ``FROM`` table."""
        query = parse_sql(sql_text)
        if _source is None:
            caller = sys._getframe(1) if hasattr(sys, "_getframe") else None
            _source = (
                (caller.f_code.co_filename, caller.f_lineno)
                if caller is not None
                else (None, None)
            )
        self._register(
            Node(
                name=name,
                kind="sql",
                parents=tuple(query.source_tables()),
                query=query,
                materialize=materialize,
                source_file=_source[0],
                source_line=_source[1],
            ),
            _module or _caller_module(),
        )

    # ----------------------------------------------------------- assembly
    def pipeline(self) -> Pipeline:
        """Mint an immutable Pipeline from the current registrations."""
        if not self._nodes:
            raise PipelineError(f"project {self.name!r} has no nodes")
        p = Pipeline(self.name)
        for node in self._nodes.values():
            p.add_node(node)
        # plain attribute, not part of Pipeline's contract: the linter
        # surfaces these as G304 findings
        p.redefinitions = dict(self.redefinitions)
        return p

    @property
    def nodes(self) -> Dict[str, Node]:
        return dict(self._nodes)

    def clear(self) -> None:
        self._nodes.clear()
        self.modules.clear()
        self.redefinitions.clear()

    def __len__(self) -> int:
        return len(self._nodes)

    def __repr__(self) -> str:
        return f"Project({self.name!r}, nodes={sorted(self._nodes)})"


# ------------------------------------------------------------ module-level
def project(name: str) -> Project:
    """Get-or-create the named project (the decorators' target registry)."""
    with _LOCK:
        if name not in _PROJECTS:
            _PROJECTS[name] = Project(name)
        return _PROJECTS[name]


def _caller_module(depth: int = 2) -> Optional[str]:
    frame = sys._getframe(depth) if hasattr(sys, "_getframe") else None
    return frame.f_globals.get("__name__") if frame is not None else None


def _loc_str(node: Node) -> str:
    if node.source_file:
        return f"{node.source_file}:{node.source_line}"
    return "<unknown location>"


def _fn_signature(f: Callable, name: Optional[str]):
    params = list(inspect.signature(f).parameters)
    if not params or params[0] != "ctx":
        raise PipelineError(
            f"model {f.__name__!r} must take ctx as its first argument"
        )
    parents = tuple(params[1:])
    if not parents:
        raise PipelineError(
            f"model {f.__name__!r} references no parent tables"
        )
    return name or f.__name__, parents


def _resolve_project(proj: Union[None, str, Project], module: Optional[str]) -> Project:
    if isinstance(proj, Project):
        return proj
    if isinstance(proj, str):
        return project(proj)
    # default: one project per defining module — import a module, get a DAG
    return project(module or "__default__")


def model(
    fn: Optional[Callable] = None,
    *,
    name: Optional[str] = None,
    project: Union[None, str, Project] = None,
    materialize: bool = False,
) -> Callable:
    """``@repro.model()`` — register a Python artifact into a project."""

    def deco(f: Callable) -> Callable:
        return _resolve_project(project, f.__module__).model(
            f, name=name, materialize=materialize
        )

    return deco(fn) if fn is not None else deco


def expectation(
    fn: Optional[Callable] = None,
    *,
    name: Optional[str] = None,
    project: Union[None, str, Project] = None,
) -> Callable:
    """``@repro.expectation()`` — register an audit into a project."""

    def deco(f: Callable) -> Callable:
        return _resolve_project(project, f.__module__).expectation(f, name=name)

    return deco(fn) if fn is not None else deco


def sql(
    name: str,
    sql_text: str,
    *,
    project: Union[None, str, Project] = None,
    materialize: bool = False,
) -> None:
    """``repro.sql("trips", "SELECT ...")`` — register a SQL artifact."""
    module = _caller_module()
    caller = sys._getframe(1) if hasattr(sys, "_getframe") else None
    source = (
        (caller.f_code.co_filename, caller.f_lineno)
        if caller is not None
        else (None, None)
    )
    _resolve_project(project, module).sql(
        name, sql_text, materialize=materialize, _module=module, _source=source
    )


# --------------------------------------------------------------- discovery
def _load_module(path: Union[str, Path]) -> ModuleType:
    """Import a pipeline file under a module name derived from its
    *resolved* path — two files that merely share a stem must not share a
    default project.  Re-importing the same file first clears its default
    project, so an edited file's deleted nodes do not linger in the DAG
    (explicitly-named projects keep overwrite semantics — they may be
    shared across modules)."""
    path = Path(path).resolve()
    # hash the resolved path rather than char-replacing it — sanitization
    # collapses distinct paths ("a_b.py" vs "a/b.py") onto one module name
    mod_name = (
        f"_repro_discovered_{path.stem}_{stable_hash(str(path), length=12)}"
    )
    with _LOCK:
        stale = _PROJECTS.get(mod_name)
    if stale is not None:
        stale.clear()
    spec = importlib.util.spec_from_file_location(mod_name, path)
    if spec is None or spec.loader is None:
        raise ImportError(f"cannot import pipeline module {path}")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[mod_name] = mod
    spec.loader.exec_module(mod)
    return mod


def discover(target: Union[str, Path, ModuleType]) -> Project:
    """Import a module (by path or object) and return the Project its
    registrations landed in — "import a module, get the DAG".

    Resolution order: a project explicitly created/named inside the module
    whose nodes the module registered; else the module's default project.
    Exactly one candidate must remain, otherwise the caller has to name
    the project explicitly (``repro.project(...)``).
    """
    mod = target if isinstance(target, ModuleType) else _load_module(target)
    with _LOCK:
        candidates = [
            p for p in _PROJECTS.values()
            if mod.__name__ in p.modules and len(p) > 0
        ]
    if len(candidates) == 1:
        return candidates[0]
    if not candidates:
        raise PipelineError(
            f"module {mod.__name__!r} registered no models — decorate "
            "functions with @repro.model()/@repro.expectation() or define "
            "PIPELINE = repro.Pipeline(...)"
        )
    raise PipelineError(
        f"module {mod.__name__!r} populated {len(candidates)} projects "
        f"({sorted(p.name for p in candidates)}); pass the project name"
    )


def resolve_pipeline(
    target: Union[Pipeline, Project, str, Path, ModuleType]
) -> Pipeline:
    """Anything run-able → an immutable Pipeline.

    Accepts a ``Pipeline`` (used as-is), a ``Project`` (minted), a module
    object, or a path to a pipeline file.  A file may either use the
    decorator SDK or define a legacy ``PIPELINE`` global — the legacy
    spelling stays supported so pre-SDK pipeline files keep running.
    """
    if isinstance(target, Pipeline):
        return target
    if isinstance(target, Project):
        return target.pipeline()
    if isinstance(target, str) and target in _PROJECTS:
        return _PROJECTS[target].pipeline()
    if isinstance(target, ModuleType):
        legacy = getattr(target, "PIPELINE", None)
        if isinstance(legacy, Pipeline):
            return legacy
        return discover(target).pipeline()
    path = Path(target)
    if not path.exists():
        raise FileNotFoundError(
            f"no pipeline at {path} (and no project named {str(target)!r})"
        )
    mod = _load_module(path)
    legacy = getattr(mod, "PIPELINE", None)
    if isinstance(legacy, Pipeline):
        return legacy
    return discover(mod).pipeline()
