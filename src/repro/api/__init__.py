"""The unified SDK facade — ``repro.Client`` and the decorator surface.

Everything user code needs lives here; the subsystem packages
(``repro.core``, ``repro.catalog``, ``repro.table``, ``repro.runtime``,
``repro.maintenance``) are the engine room.
"""
from repro.api.client import BranchHandle, CacheMaintenance, Client
from repro.api.handles import AsyncRunHandle, RunFailed, RunHandle, RunState
from repro.api.project import (
    Project,
    discover,
    expectation,
    model,
    project,
    requirements,
    resolve_pipeline,
    sql,
)

__all__ = [
    "AsyncRunHandle",
    "BranchHandle",
    "CacheMaintenance",
    "Client",
    "Project",
    "RunFailed",
    "RunHandle",
    "RunState",
    "discover",
    "expectation",
    "model",
    "project",
    "requirements",
    "resolve_pipeline",
    "sql",
]
