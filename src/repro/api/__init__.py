"""The unified SDK facade — ``repro.Client`` and the decorator surface.

Everything user code needs lives here; the subsystem packages
(``repro.core``, ``repro.catalog``, ``repro.table``, ``repro.runtime``,
``repro.maintenance``) are the engine room.
"""
from repro.analysis import Finding, LintFailed, LintReport, Severity
from repro.api.client import BranchHandle, CacheMaintenance, Client
from repro.api.handles import AsyncRunHandle, RunFailed, RunHandle, RunState
from repro.api.project import (
    Project,
    RedefinitionWarning,
    discover,
    expectation,
    model,
    project,
    requirements,
    resolve_pipeline,
    sql,
)

__all__ = [
    "AsyncRunHandle",
    "BranchHandle",
    "CacheMaintenance",
    "Client",
    "Finding",
    "LintFailed",
    "LintReport",
    "Project",
    "RedefinitionWarning",
    "RunFailed",
    "RunHandle",
    "RunState",
    "Severity",
    "discover",
    "expectation",
    "model",
    "project",
    "requirements",
    "resolve_pipeline",
    "sql",
]
