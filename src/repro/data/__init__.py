from repro.data.tokens import TokenDataset, write_token_table

__all__ = ["TokenDataset", "write_token_table"]
