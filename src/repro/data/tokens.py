"""Token data pipeline over lakehouse tables.

Training data is a TensorTable of token ids (one row per token, with a
document id column), versioned in the catalog like any other table — so a
training run is pinned to a *data commit* (the same reproducibility story
as SQL pipelines: same code + same data version = same run).

Sampling is **stateless**: ``batch_at(step)`` derives the batch purely
from (seed, step), so a restarted run resumes bit-identically without a
sampler checkpoint — the fault-tolerance primitive the training loop
relies on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.catalog.nessie import Catalog
from repro.table.format import TableFormat
from repro.table.schema import Schema

TOKEN_SCHEMA = Schema.of(token="int32", doc_id="int32")


def write_token_table(
    fmt: TableFormat,
    catalog: Catalog,
    name: str,
    tokens: np.ndarray,
    *,
    branch: str = "main",
    doc_ids: Optional[np.ndarray] = None,
) -> str:
    data = {
        "token": tokens.astype(np.int32),
        "doc_id": (
            doc_ids if doc_ids is not None else np.zeros(len(tokens))
        ).astype(np.int32),
    }
    snap = fmt.write(name, TOKEN_SCHEMA, data)
    key = fmt.manifest_key(snap)
    catalog.commit(branch, {name: key}, message=f"tokens {name}", author="data")
    return key


@dataclass
class TokenDataset:
    """Deterministic, stateless batch sampler over a token table snapshot."""

    fmt: TableFormat
    manifest_key: str
    batch_size: int
    seq_len: int
    seed: int = 0

    def __post_init__(self) -> None:
        snap = self.fmt.load_snapshot(self.manifest_key)
        self._tokens = self.fmt.read(snap, columns=["token"])["token"]
        self._n = len(self._tokens)
        if self._n < self.seq_len + 1:
            raise ValueError(
                f"token table has {self._n} tokens < seq_len+1={self.seq_len + 1}"
            )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step) — restart-exact."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        starts = rng.integers(0, self._n - self.seq_len - 1, self.batch_size)
        rows = np.stack(
            [self._tokens[s : s + self.seq_len + 1] for s in starts]
        )
        return {"tokens": rows.astype(np.int32)}
