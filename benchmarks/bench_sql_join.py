"""SQL v2 joined queries: parallel columnar feed + fused-kernel A/B.

Two scenarios pin the interactive multi-table path (``client.query`` with
zero registration) introduced with SQL v2:

* **joined_query** — JOIN + WHERE + GROUP BY + SUM over the trips/zones
  pair at reasonable-scale row counts, cold (first call, includes parse/
  route/compile) vs warm, then a kernel-vs-jnp A/B on the exec phase
  (isolated via the ``QueryExecuted`` telemetry breakdown).  Results are
  asserted byte-identical across engines — the kernel route is a perf
  knob, never a semantics knob.  The kernel runs in Pallas *interpret*
  mode on CPU (the container has no TPU), so its absolute numbers carry
  interpreter overhead; the A/B is reported, not asserted.
* **pooled_scan** — the joined query's table scans with object-store GET
  latency restored (see ``bench_parallel_dag._S3LikeStore``), serial vs
  pooled with kernel-sized work items (``KERNEL_CHUNK_ROWS``).
  Acceptance: **>= 2x wall-clock for the pooled feed**, byte-identical
  concatenation.

Also runnable standalone for the CI smoke-bench job::

    python -m benchmarks.bench_sql_join --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

import numpy as np

from benchmarks.bench_parallel_dag import _S3LikeStore
from benchmarks.common import bench, perf_meta, row
from repro.api import Client
from repro.table import Predicate, TableFormat, execute_scan, plan_scan
from repro.table.scan import KERNEL_CHUNK_ROWS
from repro.table.schema import Schema

#: group-key cardinality (well under route.py's 1024-group ceiling)
N_ZONES = 256

JOIN_SQL = """
SELECT z.borough, COUNT(*) AS trips, SUM(t.fare) AS total_fare
FROM trips AS t JOIN zones AS z ON t.zone = z.zone_id
WHERE t.distance > 5
GROUP BY z.borough ORDER BY z.borough
"""


def _make_tables(n: int, rng: np.random.Generator) -> Dict[str, Dict]:
    # int32 columns with value ranges the router can prove f32-exact at
    # this row count (max * n < 2^24), so engine="auto" takes the kernel
    return {
        "trips": {
            "zone": rng.integers(0, N_ZONES, n).astype(np.int32),
            "fare": rng.integers(1, 64, n).astype(np.int32),
            "distance": rng.integers(0, 30, n).astype(np.int32),
        },
        "zones": {
            "zone_id": np.arange(N_ZONES, dtype=np.int32),
            "borough": (np.arange(N_ZONES, dtype=np.int32) % 16) + 100,
        },
    }


def _exec_s(client: Client, engine: str, iters: int = 3) -> float:
    """Min exec-phase seconds over ``iters`` warm calls, read from the
    query's own ``QueryExecuted`` telemetry breakdown."""
    best = float("inf")
    for _ in range(iters):
        client.query(JOIN_SQL, engine=engine)
        ev = [e for e in client.events() if type(e).__name__ == "QueryExecuted"][-1]
        assert ev.engine_path == ("kernel" if engine == "kernel" else "jnp")
        best = min(best, ev.exec_s)
    return best


def _joined_query(n: int, rng: np.random.Generator) -> Dict:
    data = _make_tables(n, rng)
    with Client.ephemeral() as client:
        for name, cols in data.items():
            client.write_table(name, cols)

        t0 = time.perf_counter()
        cold = client.query(JOIN_SQL)  # auto -> kernel on this data
        cold_s = time.perf_counter() - t0
        ev = [e for e in client.events() if type(e).__name__ == "QueryExecuted"][-1]
        assert ev.engine_path == "kernel", (
            f"auto should route this query to the kernel, got {ev.engine_path!r}"
        )

        warm_s = bench(lambda: client.query(JOIN_SQL), warmup=0, iters=3)
        by_engine = {
            eng: client.query(JOIN_SQL, engine=eng) for eng in ("kernel", "jnp")
        }
        for k in cold:
            np.testing.assert_array_equal(by_engine["kernel"][k], by_engine["jnp"][k])
            assert by_engine["kernel"][k].dtype == by_engine["jnp"][k].dtype
            np.testing.assert_array_equal(cold[k], by_engine["jnp"][k])

        kernel_exec_s = _exec_s(client, "kernel")
        jnp_exec_s = _exec_s(client, "jnp")
    # even with interpreter overhead the one-hot kernel pipeline beats the
    # sort-based jnp groupby at these shapes (~1.9x observed); hold the
    # conservative "no slower" floor so a routing regression (kernel path
    # silently degrading) fails the smoke bench
    assert jnp_exec_s / max(kernel_exec_s, 1e-9) >= 1.0, (
        f"kernel exec {kernel_exec_s:.4f}s slower than jnp {jnp_exec_s:.4f}s"
    )
    return {
        "rows": n,
        "groups": int(len(cold["borough"])),
        "cold_s": cold_s,
        "warm_s": warm_s,
        "kernel_exec_s": kernel_exec_s,
        "jnp_exec_s": jnp_exec_s,
        "kernel_vs_jnp": jnp_exec_s / max(kernel_exec_s, 1e-9),
        "interpret_mode": True,
        "engines_byte_identical": True,
    }


def _pooled_scan(n: int, rng: np.random.Generator) -> Dict:
    """Serial vs pooled+chunked reads of the joined query's two scans."""
    n_scan = max(n * 2, 100_000)
    shard_rows = max(2048, n_scan // 48)  # ~48 shards to overlap
    fmt = TableFormat(
        _S3LikeStore(tempfile.mkdtemp(prefix="repro_sqljoin_")),
        shard_rows=shard_rows,
    )
    data = _make_tables(n_scan, rng)
    snaps = {
        name: fmt.write(
            name,
            Schema.of(**{c: str(a.dtype) for c, a in cols.items()}),
            cols,
        )
        for name, cols in data.items()
    }
    # exactly the plans Runner.query builds: pruned columns + the pushed
    # primary-table conjunct
    plans = {
        "trips": plan_scan(
            snaps["trips"],
            columns=["zone", "fare"],
            predicates=[Predicate("distance", ">", 5)],
        ),
        "zones": plan_scan(snaps["zones"], columns=["zone_id", "borough"]),
    }

    def scan_all(pool, chunk_rows):
        return {
            t: execute_scan(fmt, p, pool=pool, chunk_rows=chunk_rows)
            for t, p in plans.items()
        }

    with ThreadPoolExecutor(max_workers=8, thread_name_prefix="scan") as pool:
        serial = scan_all(None, None)
        pooled = scan_all(pool, KERNEL_CHUNK_ROWS)
        for t in serial:
            for c in serial[t]:
                np.testing.assert_array_equal(serial[t][c], pooled[t][c])
        t_serial = bench(lambda: scan_all(None, None), warmup=1, iters=3)
        t_pooled = bench(
            lambda: scan_all(pool, KERNEL_CHUNK_ROWS), warmup=1, iters=3
        )
    speedup = t_serial / max(t_pooled, 1e-9)
    assert speedup >= 2.0, (
        f"pooled joined-scan speedup {speedup:.2f}x < 2x acceptance floor"
    )
    return {
        "rows": n_scan,
        "shards": sum(len(p.shards) for p in plans.values()),
        "chunk_rows": KERNEL_CHUNK_ROWS,
        "get_latency_s": _S3LikeStore.GET_LATENCY_S,
        "serial_wall_s": t_serial,
        "pooled_wall_s": t_pooled,
        "speedup": speedup,
    }


def run(n: int = 200_000, json_path: Optional[str] = None) -> List[str]:
    rng = np.random.default_rng(0)
    out: List[str] = []

    q = _joined_query(n, rng)
    out.append(
        row(
            "sql_join_query",
            q["warm_s"] * 1e6,
            f"rows={q['rows']};groups={q['groups']};cold_s={q['cold_s']:.3f};"
            f"kernel_exec_s={q['kernel_exec_s']:.4f};"
            f"jnp_exec_s={q['jnp_exec_s']:.4f};"
            f"kernel_vs_jnp={q['kernel_vs_jnp']:.2f}x(interpret);"
            "byte_identical=yes",
        )
    )

    s = _pooled_scan(n, rng)
    out.append(
        row(
            "sql_join_pooled_scan",
            s["pooled_wall_s"] * 1e6,
            f"rows={s['rows']};shards={s['shards']};"
            f"serial_s={s['serial_wall_s']:.3f};"
            f"speedup={s['speedup']:.2f}x(>=2x asserted)",
        )
    )

    if json_path is not None:
        results = {
            "benchmark": "sql_join",
            "n": n,
            "scenarios": {
                "joined_query": {
                    **q,
                    **perf_meta(parallelism=1, wall_s=q["warm_s"]),
                },
                "pooled_scan": {
                    **s,
                    **perf_meta(
                        parallelism=8,
                        wall_s=s["pooled_wall_s"],
                        sequential_wall_s=s["serial_wall_s"],
                    ),
                },
            },
        }
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small row count for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write scenario metrics as JSON")
    args = ap.parse_args()
    for line in run(n=20_000 if args.smoke else 200_000, json_path=args.json):
        print(line)
