"""Benchmark harness — one module per paper table/figure/claim.

Prints ``name,us_per_call,derived`` CSV rows:

  bench_fusion            paper 4.4.2 (the 5x fused-plan claim)
  bench_serverless        paper 4.5 (warm/cold starts, 300 ms claim)
  bench_reasonable_scale  paper 3.1 / Fig. 1 (power-law workloads)
  bench_engine            query engine + fused_filter_agg kernel
  bench_catalog           paper 4.3 (branch/commit/merge, checkpoints)
  bench_differential_cache  warm re-runs skip clean stages (arXiv 2411.08203)
  bench_maintenance       lakekeeper: gc bytes reclaimed, compaction speedup
  bench_speculation       straggler-tail savings from backup requests
  bench_parallel_dag      wave scheduler: fan-out speedup vs sequential
  bench_scheduler         Scheduler v2: critical-path order + streaming
  bench_sql_join          SQL v2: joined queries, kernel A/B, pooled feed
  bench_dryrun_summary    deliverables (e)+(g): dry-run + roofline headlines
  bench_telemetry         event-bus overhead (< 3% of run wall-clock)

Run: ``PYTHONPATH=src:. python -m benchmarks.run [--only NAME]``
"""
import argparse
import sys
import traceback

SUITES = [
    "bench_reasonable_scale",
    "bench_serverless",
    "bench_catalog",
    "bench_engine",
    "bench_fusion",
    "bench_differential_cache",
    "bench_maintenance",
    "bench_speculation",
    "bench_parallel_dag",
    "bench_scheduler",
    "bench_sql_join",
    "bench_dryrun_summary",
    "bench_telemetry",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single suite")
    args = ap.parse_args()
    suites = [args.only] if args.only else SUITES
    print("name,us_per_call,derived")
    failed = 0
    for name in suites:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:
            failed += 1
            print(f"{name},ERROR,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
