"""Catalog/versioning overheads (paper 4.3): branch, commit, merge,
ephemeral-run lifecycle, and checkpoint save/restore throughput."""
from __future__ import annotations

import tempfile
from typing import List

import jax
import numpy as np

from benchmarks.common import bench, row
from repro.catalog import Catalog
from repro.io import ObjectStore
from repro.table import Schema, TableFormat


def run() -> List[str]:
    out = []
    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store, shard_rows=65536)
    rng = np.random.default_rng(0)
    counter = [0]

    def commit():
        counter[0] += 1
        catalog.commit("main", {f"t{counter[0] % 7}": f"key{counter[0]}"})

    out.append(row("catalog_commit", bench(commit, iters=20) * 1e6, ""))

    def branch_cycle():
        counter[0] += 1
        name = f"run_{counter[0]}"
        catalog.create_branch(name)
        catalog.commit(name, {"x": f"k{counter[0]}"})
        catalog.merge(name, "main", delete_source=True)

    out.append(
        row("catalog_ephemeral_branch_cycle", bench(branch_cycle, iters=10) * 1e6,
            "create+commit+merge+delete (Fig.4 lifecycle)")
    )

    # table write/read throughput
    schema = Schema.of(a="float32", b="int32")
    data = {
        "a": rng.random(1_000_000).astype(np.float32),
        "b": rng.integers(0, 100, 1_000_000).astype(np.int32),
    }

    def write():
        counter[0] += 1
        fmt.write(f"tbl{counter[0] % 3}", schema, data)

    tw = bench(write, iters=3)
    snap = fmt.write("tbl_read", schema, data)
    tr = bench(lambda: fmt.read(snap), iters=3)
    mb = 8 * 1_000_000 / 1e6
    out.append(row("table_write_1m_rows", tw * 1e6, f"MBps={mb / tw:.0f}"))
    out.append(row("table_read_1m_rows", tr * 1e6, f"MBps={mb / tr:.0f}"))

    # checkpoint save/restore (100M-param-scale tree)
    from repro.train import CheckpointManager

    params = {
        f"w{i}": jax.numpy.asarray(rng.standard_normal((1024, 1024)).astype(np.float32))
        for i in range(12)
    }
    mgr = CheckpointManager(catalog, prefix="models/bench")
    ts = bench(lambda: mgr.save(params, branch="main", step=counter[0]), iters=3)
    like = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params
    )
    trr = bench(lambda: mgr.restore(like, branch="main"), iters=3)
    pbytes = 12 * 1024 * 1024 * 4 / 1e6
    out.append(row("checkpoint_save_48MB", ts * 1e6, f"MBps={pbytes / ts:.0f}"))
    out.append(row("checkpoint_restore_48MB", trr * 1e6, f"MBps={pbytes / trr:.0f}"))
    return out
