"""Deliverables (e)+(g) as CSV: dry-run coverage + roofline headlines.

Reads the cached artifacts in results/ (produced by repro.launch.dryrun /
roofline) — no compilation happens here.  Skipped gracefully when the
dry-run has not been executed in this checkout.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List

from benchmarks.common import row

RESULTS = Path(__file__).resolve().parents[1] / "results"


def run() -> List[str]:
    out = []
    dryrun_path = RESULTS / "dryrun.json"
    if not dryrun_path.exists():
        return [row("dryrun_summary", 0.0, "results/dryrun.json absent — run repro.launch.dryrun")]
    r = json.loads(dryrun_path.read_text())
    base = {k: v for k, v in r.items() if "@" not in k}
    ok = sum(1 for v in base.values() if v.get("ok"))
    skipped = sum(1 for v in base.values() if "skipped" in v)
    failed = sum(1 for v in base.values() if v.get("ok") is False)
    compile_s = sum(v.get("compile_s", 0.0) for v in base.values() if v.get("ok"))
    out.append(
        row(
            "dryrun_cells",
            compile_s * 1e6 / max(ok, 1),
            f"ok={ok};skipped={skipped};failed={failed};"
            f"meshes=16x16+2x16x16;total_compile_s={compile_s:.0f}",
        )
    )
    fits = sum(
        1
        for v in base.values()
        if v.get("ok")
        and ((v["memory"]["argument_bytes"] or 0) + (v["memory"]["temp_bytes"] or 0))
        <= 16 * 2**30
    )
    out.append(row("dryrun_fits_16gb", 0.0, f"{fits}/{ok} cells within v5e HBM"))

    roofline_path = RESULTS / "roofline.json"
    if roofline_path.exists():
        rl = json.loads(roofline_path.read_text())
        live = {k: v for k, v in rl.items() if "terms_s" in v}
        if live:
            best = max(live.items(), key=lambda kv: kv[1]["roofline_fraction"])
            doms = {}
            for v in live.values():
                doms[v["dominant"]] = doms.get(v["dominant"], 0) + 1
            out.append(
                row(
                    "roofline_cells",
                    0.0,
                    f"n={len(live)};dominant_hist={doms};"
                    f"best_frac={best[1]['roofline_fraction']:.2f}@{best[0]}",
                )
            )
    return out
