"""Benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Tuple


def bench(fn: Callable[[], None], *, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
