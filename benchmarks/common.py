"""Benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple


def bench(fn: Callable[[], None], *, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def perf_meta(
    *,
    parallelism: int,
    wall_s: float,
    sequential_wall_s: Optional[float] = None,
) -> Dict[str, float]:
    """Standard perf-trajectory fields for emitted bench JSON.

    Every benchmark that writes a ``BENCH_*.json`` / CI artifact should
    stamp its scenarios with these so wall-clock numbers stay comparable
    across PRs: the parallelism level the scenario ran at, its wall
    seconds, and (when a parallelism-1 baseline exists) the speedup
    against that sequential run.
    """
    meta: Dict[str, float] = {
        "parallelism": parallelism,
        "wall_s": wall_s,
    }
    if sequential_wall_s is not None:
        meta["speedup_vs_sequential"] = sequential_wall_s / max(wall_s, 1e-9)
    return meta
