"""Scheduler v2: cost-aware critical-path ordering + streaming handoff.

Two scenarios pin the scheduler's wall-clock claims, each with the
byte-identity cross-check (ordering and streaming are throughput knobs,
never semantics knobs):

* **straggler_dag** — 16 short "wide" stages registered first (low stage
  ids) plus a 6-deep chain of slower stages registered last (high stage
  ids), at parallelism 4.  Legacy ``stage_id`` order drains every wide
  stage before it touches the chain, so the chain's serial tail lands on
  an empty fleet; ``critical_path`` dispatches the chain head first (its
  longest-path-to-sink weight dominates, even cold on the bytes
  heuristic) and the wides fill the remaining slots around it.
  Acceptance: **>= 1.3x wall-clock for critical_path vs stage_id**.
* **streaming_chain** — a 4-deep scan→transform chain where every stage
  emits a wide artifact against a store with S3-like PUT latency.  With
  the stage barrier, each stage's exec waits for its parent's artifact
  writes; with streaming, downstream exec overlaps upstream store I/O
  (outputs-ready handoff) and scans run through the incremental shard
  iterator.  Acceptance: **>= 1.5x wall-clock for streaming vs
  barrier**, and the Scheduler-v2 default mode is never slower than the
  legacy (PR 5) mode on the same fixture.

Also runnable standalone for the CI smoke-bench job::

    python -m benchmarks.bench_scheduler --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import perf_meta, row
from repro.api import Client
from repro.core import Pipeline
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.runtime import ExecutorConfig

#: straggler DAG shape: WIDE short stages (low ids) + a CHAIN_DEPTH-deep
#: chain of slower stages (high ids), scheduled at PARALLELISM in flight
WIDE = 16
CHAIN_DEPTH = 6
PARALLELISM = 4

#: streaming chain shape: depth of the scan→transform chain and the
#: simulated object-store PUT latency its artifact writes pay
STREAM_DEPTH = 4
PUT_LATENCY_S = 0.02


def _named_link(name: str, prev: str, body):
    """A pipeline fn with a real named parameter (``Pipeline.python``
    infers the dependency edge from the signature), delegating to
    ``body(ctx, upstream)``."""
    ns = {"_body": body}
    exec(
        f"def {name}(ctx, {prev}):\n    return _body(ctx, {prev})\n",
        ns,
    )
    return ns[name]


def _sleeper(latency_s: float, salt: int):
    """Host callback with deterministic output and fixed latency — the
    serverless stand-in for remote work the scheduler must overlap."""

    def fn(counts: np.ndarray) -> np.ndarray:
        time.sleep(latency_s)
        return np.float32(np.asarray(counts, dtype=np.float32).sum() + salt)

    return fn


def build_straggler_pipeline(
    *, wide_s: float, chain_s: float
) -> Pipeline:
    """WIDE quick stages registered FIRST (low stage ids), then the
    slower chain — the adversarial layout for stage-id order."""
    p = Pipeline("scheduler_straggler")
    for i in range(WIDE):

        def make_wide(i: int):
            def fn(ctx, taxi_table):
                score = jax.pure_callback(
                    _sleeper(wide_s, i),
                    jax.ShapeDtypeStruct((), jnp.float32),
                    taxi_table.column("passenger_count"),
                )
                return {"score": score[None]}

            fn.__name__ = f"wide_{i}"
            return fn

        p.python(make_wide(i))

    def chain_0(ctx, taxi_table):
        score = jax.pure_callback(
            _sleeper(chain_s, 100),
            jax.ShapeDtypeStruct((), jnp.float32),
            taxi_table.column("passenger_count"),
        )
        return {"score": score[None]}

    p.python(chain_0)
    for k in range(1, CHAIN_DEPTH):

        def make_body(k: int):
            def body(ctx, upstream):
                score = jax.pure_callback(
                    _sleeper(chain_s, 100 + k),
                    jax.ShapeDtypeStruct((), jnp.float32),
                    upstream.column("score"),
                )
                return {"score": score[None]}

            return body

        p.python(_named_link(f"chain_{k}", f"chain_{k - 1}", make_body(k)))
    return p


def _run_mode(
    data: Dict[str, np.ndarray],
    pipeline: Pipeline,
    *,
    schedule: str,
    streaming: bool,
    put_latency_s: float = 0.0,
) -> Dict:
    """One fresh lake, one cold run in the given mode (fixed parallelism
    isolates ordering/streaming from fleet sizing)."""
    with Client.ephemeral(
        shard_rows=16_384,
        executor_config=ExecutorConfig(
            max_workers=max(8, PARALLELISM * 2),
            max_concurrent_stages=PARALLELISM,
        ),
    ) as client:
        client.write_table("taxi_table", data, schema=TAXI_SCHEMA)
        if put_latency_s > 0.0:
            # layer S3-like blob-write latency back on AFTER the fixture
            # lands (the local filesystem hides the round trip streaming
            # overlaps; production pays it on every artifact shard)
            orig_put = client.store.put

            def slow_put(payload: bytes) -> str:
                time.sleep(put_latency_s)
                return orig_put(payload)

            client.store.put = slow_put
        t0 = time.perf_counter()
        # fusion off: the scheduler benchmark needs the DAG's real shape
        # (a fused linear chain is one stage — nothing left to order)
        handle = client.run(
            pipeline,
            cache=False,
            fusion=False,
            pushdown=False,
            parallelism=PARALLELISM,
            schedule=schedule,
            streaming=streaming,
        )
        wall = time.perf_counter() - t0
        handle.raise_for_state()
        sched = handle.stats["scheduler"]
        return {
            "wall_s": wall,
            "artifacts": dict(handle.artifacts),
            "checks": dict(handle.checks),
            "schedule": sched["schedule"],
            "streaming": sched["streaming"],
            "critical_path": sched["critical_path"],
        }


def _straggler_scenario(n: int, *, wide_s: float, chain_s: float) -> Dict:
    data = make_taxi_data(n, np.random.default_rng(0))
    pipeline = build_straggler_pipeline(wide_s=wide_s, chain_s=chain_s)
    # streaming off in BOTH modes: this scenario isolates dispatch order
    legacy = _run_mode(data, pipeline, schedule="stage_id", streaming=False)
    crit = _run_mode(data, pipeline, schedule="critical_path", streaming=False)
    assert crit["artifacts"] == legacy["artifacts"], (
        "ordering mode changed artifact manifests — schedule must never "
        "be a semantics knob"
    )
    # the cost model must actually have found the chain: its predicted
    # critical path is the chain stages (ids WIDE..WIDE+CHAIN_DEPTH-1)
    assert crit["critical_path"] == list(range(WIDE, WIDE + CHAIN_DEPTH)), (
        f"predicted critical path {crit['critical_path']} is not the chain"
    )
    speedup = legacy["wall_s"] / max(crit["wall_s"], 1e-9)
    assert speedup >= 1.3, (
        f"critical-path speedup {speedup:.2f}x < 1.3x acceptance floor "
        f"(stage_id {legacy['wall_s']:.2f}s vs critical_path "
        f"{crit['wall_s']:.2f}s)"
    )
    return {
        "n": n,
        "wide": WIDE,
        "chain_depth": CHAIN_DEPTH,
        "parallelism": PARALLELISM,
        "wide_s": wide_s,
        "chain_s": chain_s,
        "stage_id_wall_s": legacy["wall_s"],
        "critical_path_wall_s": crit["wall_s"],
        "speedup": speedup,
    }


def build_stream_chain(depth: int = STREAM_DEPTH) -> Pipeline:
    """A scan→transform chain where every stage emits a full-width
    artifact — store writes dominate, the streaming handoff's best case."""
    p = Pipeline("scheduler_stream")

    def link_0(ctx, taxi_table):
        col = taxi_table.column("passenger_count").astype(jnp.float32)
        return {"vals": col * 2.0}

    p.python(link_0)
    for k in range(1, depth):
        p.python(_named_link(
            f"link_{k}",
            f"link_{k - 1}",
            lambda ctx, upstream: {"vals": upstream.column("vals") + 1.0},
        ))
    return p


def _streaming_scenario(n: int, put_latency_s: float) -> Dict:
    data = make_taxi_data(n, np.random.default_rng(1))
    pipeline = build_stream_chain()
    barrier = _run_mode(
        data, pipeline, schedule="critical_path", streaming=False,
        put_latency_s=put_latency_s,
    )
    streaming = _run_mode(
        data, pipeline, schedule="critical_path", streaming=True,
        put_latency_s=put_latency_s,
    )
    # the PR-5 floor: the v2 default mode must never lose to the legacy
    # scheduler on the same fixture
    legacy = _run_mode(
        data, pipeline, schedule="stage_id", streaming=False,
        put_latency_s=put_latency_s,
    )
    assert streaming["artifacts"] == barrier["artifacts"] == legacy["artifacts"], (
        "streaming changed artifact manifests — streaming must never be "
        "a semantics knob"
    )
    speedup = barrier["wall_s"] / max(streaming["wall_s"], 1e-9)
    assert speedup >= 1.5, (
        f"streaming speedup {speedup:.2f}x < 1.5x acceptance floor "
        f"(barrier {barrier['wall_s']:.2f}s vs streaming "
        f"{streaming['wall_s']:.2f}s)"
    )
    vs_legacy = legacy["wall_s"] / max(streaming["wall_s"], 1e-9)
    assert vs_legacy >= 1.0, (
        f"Scheduler v2 default mode is {1 / vs_legacy:.2f}x SLOWER than "
        f"the legacy stage_id scheduler — the no-regression floor"
    )
    return {
        "n": n,
        "depth": STREAM_DEPTH,
        "parallelism": PARALLELISM,
        "put_latency_s": put_latency_s,
        "barrier_wall_s": barrier["wall_s"],
        "streaming_wall_s": streaming["wall_s"],
        "legacy_wall_s": legacy["wall_s"],
        "speedup": speedup,
        "speedup_vs_legacy": vs_legacy,
    }


def run(
    n: int = 50_000,
    *,
    wide_s: float = 0.075,
    chain_s: float = 0.1,
    put_latency_s: float = PUT_LATENCY_S,
    json_path: Optional[str] = None,
) -> List[str]:
    straggler = _straggler_scenario(n, wide_s=wide_s, chain_s=chain_s)
    stream = _streaming_scenario(n, put_latency_s)

    out = [
        row(
            f"scheduler_straggler_w{WIDE}_c{CHAIN_DEPTH}_p{PARALLELISM}",
            straggler["critical_path_wall_s"] * 1e6,
            f"stage_id={straggler['stage_id_wall_s'] * 1e6:.0f}us;"
            f"speedup={straggler['speedup']:.2f}x;target>=1.3x;"
            f"identical_artifacts=True",
        ),
        row(
            f"scheduler_streaming_chain{STREAM_DEPTH}_n{stream['n']}",
            stream["streaming_wall_s"] * 1e6,
            f"barrier={stream['barrier_wall_s'] * 1e6:.0f}us;"
            f"speedup={stream['speedup']:.2f}x;target>=1.5x;"
            f"vs_legacy={stream['speedup_vs_legacy']:.2f}x;"
            f"identical_artifacts=True",
        ),
    ]

    if json_path is not None:
        results = {
            "straggler_dag": {
                **straggler,
                **perf_meta(
                    parallelism=PARALLELISM,
                    wall_s=straggler["critical_path_wall_s"],
                    sequential_wall_s=straggler["stage_id_wall_s"],
                ),
            },
            "streaming_chain": {
                **stream,
                **perf_meta(
                    parallelism=PARALLELISM,
                    wall_s=stream["streaming_wall_s"],
                    sequential_wall_s=stream["barrier_wall_s"],
                ),
            },
            "floors": {
                "critical_path_vs_stage_id": 1.3,
                "streaming_vs_barrier": 1.5,
                "v2_default_vs_legacy": 1.0,
            },
        }
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=50_000, help="taxi rows")
    ap.add_argument("--smoke", action="store_true",
                    help="small fixture + shorter sleeps (CI smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write scenario metrics as JSON (CI artifact)")
    args = ap.parse_args()
    # smoke keeps sleeps long enough to dominate fixed overhead on a
    # loaded 2-core CI runner while the whole suite stays under a minute
    n = 20_000 if args.smoke else args.n
    wide_s = 0.05 if args.smoke else 0.075
    chain_s = 0.07 if args.smoke else 0.1
    print("name,us_per_call,derived")
    for line in run(
        n=n, wide_s=wide_s, chain_s=chain_s, json_path=args.json
    ):
        print(line, flush=True)


if __name__ == "__main__":
    main()
