"""Straggler speculation: quantify tail savings (ROADMAP item from PR 1).

The executor duplicates a task once it runs longer than
``speculation_factor`` x the median of its completed siblings, and the
first successful finisher wins.  PR 1 fixed the trigger (the median was
previously measured against the wall clock, so speculation could never
fire); this benchmark measures what that fix buys on a classic fan-out
with one slow container:

* N sibling tasks, each ~``base_s`` of work;
* one straggler whose FIRST attempt takes ``tail_s`` (a degraded
  container); any duplicate attempt runs at normal speed;
* speculation ON should cut the batch wall time from ~``tail_s`` to
  ~``factor x base_s + base_s`` — the duplicate races past the straggler.
"""
from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

from benchmarks.common import row
from repro.runtime import ExecutorConfig, FunctionSpec, ServerlessExecutor

N_TASKS = 8
BASE_S = 0.05
TAIL_S = 0.8


def _make_siblings():
    """Fresh task set: task 0's first attempt is slow, later attempts
    (the speculated duplicate) run at base speed."""
    attempts = {"n": 0}
    lock = threading.Lock()

    def straggler(x):
        with lock:
            attempts["n"] += 1
            first = attempts["n"] == 1
        time.sleep(TAIL_S if first else BASE_S)
        return np.asarray(x) + 1

    def normal(x):
        time.sleep(BASE_S)
        return np.asarray(x) + 1

    return [
        (
            FunctionSpec(name=f"sib{i}", fn=straggler if i == 0 else normal, jit=False),
            (np.ones(4),),
        )
        for i in range(N_TASKS)
    ]


def _run_batch(speculation_factor: float) -> float:
    cfg = ExecutorConfig(
        max_workers=N_TASKS + 2,
        speculation_factor=speculation_factor,
        speculation_min_samples=3,
    )
    with ServerlessExecutor(cfg) as ex:
        t0 = time.perf_counter()
        results = ex.map_with_speculation(_make_siblings())
        wall = time.perf_counter() - t0
        for r in results:
            np.testing.assert_allclose(r, 2.0)
        speculated = ex.stats()["speculated"]
    return wall, speculated


def run() -> List[str]:
    # factor so large the straggler can never trip it = speculation off
    wall_off, spec_off = _run_batch(speculation_factor=1e9)
    wall_on, spec_on = _run_batch(speculation_factor=2.0)

    assert spec_off == 0, "control run must not speculate"
    assert spec_on >= 1, "straggler should have been speculated"
    # the duplicate must beat the straggler's tail by a wide margin
    savings = wall_off - wall_on
    speedup = wall_off / max(wall_on, 1e-9)
    assert wall_on < TAIL_S, "speculation failed to cut the tail"
    return [
        row(
            f"speculation_off_tail{int(TAIL_S * 1e3)}ms",
            wall_off * 1e6,
            f"batch={N_TASKS};duplicates=0;wall~=tail",
        ),
        row(
            f"speculation_on_tail{int(TAIL_S * 1e3)}ms",
            wall_on * 1e6,
            f"batch={N_TASKS};duplicates={spec_on};"
            f"tail_savings={savings * 1e3:.0f}ms;speedup={speedup:.2f}x",
        ),
    ]
