"""Telemetry plane overhead: the event bus must be effectively free.

The observability contract (ROADMAP: event bus + run tracing) only holds
if instrumentation does not tax the runs it observes.  Two measurements
pin that down:

* **micro** — raw ``EventBus.publish`` cost with realistic fan-out (two
  bounded subscribers + the on-disk spool mirror), in µs/event; from it
  and the event count of a real traced run, the *derived* bus share of
  that run's wall-clock.
* **macro** — the same pipeline executed end-to-end with telemetry ON
  (bus + spool + runlog persistence + metrics) vs OFF
  (``Client(telemetry=False)``), interleaved A/B to cancel drift,
  medians compared.  Cache is disabled so every run does full work.

Acceptance (enforced here, run by the CI telemetry-smoke job): bus
overhead **< 3%** of run wall-clock on both measurements.

Also runnable standalone::

    python -m benchmarks.bench_telemetry --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import perf_meta, row
from repro.api import Client
from repro.core import Pipeline
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.runtime import ExecutorConfig
from repro.telemetry import EventBus, ScanShardRead

#: acceptance bar: bus share of run wall-clock
MAX_OVERHEAD_FRAC = 0.03


def _pipeline() -> Pipeline:
    p = Pipeline("telemetry_bench")
    p.sql(
        "trips",
        "SELECT pickup_location_id, passenger_count as count FROM taxi_table"
        " WHERE pickup_at >= '2019-04-01'",
    )

    @p.python
    def trips_expectation(ctx, trips):
        return trips.mean("count") > 0.0

    for i in range(3):

        def make_model(i):
            def fn(ctx, trips):
                import jax.numpy as jnp

                col = trips.column("count").astype(jnp.float32)
                return {"stat": jnp.sort(col) * (i + 1)}

            fn.__name__ = f"m{i}"
            return fn

        p.python(make_model(i))
    return p


def _client(telemetry: bool) -> Client:
    return Client.ephemeral(
        shard_rows=2048,
        telemetry=telemetry,
        executor_config=ExecutorConfig(max_workers=8, max_concurrent_stages=4),
    )


def _measure_publish_us(n: int = 20_000) -> float:
    """µs per publish with two subscribers + a live spool file."""
    with tempfile.TemporaryDirectory() as tmp:
        bus = EventBus(spool_path=Path(tmp) / "spool.jsonl")
        subs = [bus.subscribe(maxlen=1024) for _ in range(2)]
        ev = [
            ScanShardRead(run_id=1, table="t", shard_index=i, rows_in=100)
            for i in range(n)
        ]
        t0 = time.perf_counter()
        for e in ev:
            bus.publish(e)
        wall = time.perf_counter() - t0
        for s in subs:
            s.close()
        bus.close()
    return wall / n * 1e6


def _run_wall(client: Client, pipeline: Pipeline, rows: int, seed: int) -> float:
    rng = np.random.default_rng(seed)
    client.write_table(
        "taxi_table", make_taxi_data(rows, rng), schema=TAXI_SCHEMA
    )
    walls = []
    for _ in range(1):  # branch state is fresh per client; one run each
        t0 = time.perf_counter()
        client.run(
            pipeline, fusion=False, pushdown=False, cache=False
        ).raise_for_state()
        walls.append(time.perf_counter() - t0)
    return min(walls)


def measure(
    *, rows: int = 20_000, pairs: int = 5, json_path: Optional[str] = None
) -> Dict[str, float]:
    pipeline = _pipeline()

    # macro: interleaved A/B — fresh lake per run, medians compared
    on_walls: List[float] = []
    off_walls: List[float] = []
    for i in range(pairs + 1):  # +1 warmup pair (jit compile both sides)
        for telemetry, acc in ((True, on_walls), (False, off_walls)):
            with _client(telemetry) as client:
                wall = _run_wall(client, pipeline, rows, seed=7)
                if i > 0:
                    acc.append(wall)
    on_med = statistics.median(on_walls)
    off_med = statistics.median(off_walls)
    e2e_overhead = max(0.0, (on_med - off_med) / off_med)

    # micro: publish cost x observed event volume = derived bus share
    publish_us = _measure_publish_us()
    with _client(True) as client:
        wall = _run_wall(client, pipeline, rows, seed=7)
        run_id = max(
            ref["run_id"] for ref in client.runlog.refs().values()
        )
        n_events = len(client.runlog.get(run_id))
    derived_share = (n_events * publish_us * 1e-6) / wall

    results = {
        "publish_us_per_event": publish_us,
        "events_per_run": n_events,
        "run_wall_s": wall,
        "derived_bus_share": derived_share,
        "wall_on_s": on_med,
        "wall_off_s": off_med,
        "e2e_overhead_frac": e2e_overhead,
        **perf_meta(parallelism=4, wall_s=on_med),
    }
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)

    assert derived_share < MAX_OVERHEAD_FRAC, (
        f"bus share of wall-clock {derived_share:.2%} exceeds "
        f"{MAX_OVERHEAD_FRAC:.0%} ({n_events} events x {publish_us:.1f}µs "
        f"over {wall:.3f}s)"
    )
    assert e2e_overhead < MAX_OVERHEAD_FRAC, (
        f"end-to-end telemetry overhead {e2e_overhead:.2%} exceeds "
        f"{MAX_OVERHEAD_FRAC:.0%} (on={on_med:.3f}s off={off_med:.3f}s)"
    )
    return results


def run() -> List[str]:
    r = measure()
    return [
        row("telemetry_publish", r["publish_us_per_event"],
            f"2 subs + spool; {r['events_per_run']} events/run"),
        row("telemetry_run_on", r["wall_on_s"] * 1e6,
            f"e2e_overhead={r['e2e_overhead_frac']:.2%}"),
        row("telemetry_run_off", r["wall_off_s"] * 1e6,
            f"derived_bus_share={r['derived_bus_share']:.3%}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--pairs", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: fewer rows, fewer pairs")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    if args.smoke:
        args.rows, args.pairs = 10_000, 3
    r = measure(rows=args.rows, pairs=args.pairs, json_path=args.json)
    print(
        f"publish: {r['publish_us_per_event']:.2f} µs/event | "
        f"{r['events_per_run']} events/run -> derived bus share "
        f"{r['derived_bus_share']:.3%} of {r['run_wall_s']:.3f}s wall"
    )
    print(
        f"end-to-end: on={r['wall_on_s']:.3f}s off={r['wall_off_s']:.3f}s "
        f"overhead={r['e2e_overhead_frac']:.2%} (bar {MAX_OVERHEAD_FRAC:.0%})"
    )


if __name__ == "__main__":
    main()
