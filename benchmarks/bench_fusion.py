"""Paper 4.4.2: fused physical plan vs naive isomorphic plan.

The paper reports a 5x faster feedback loop from pushing filters into the
scan and running SQL + Python expectation in one process.  We measure the
same pipeline (the Appendix taxi DAG) under both planner modes, on
several data scales, reporting wall time and object-store traffic.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import bench, row
from repro.api import Client
from repro.examples_data import TAXI_SCHEMA, build_taxi_pipeline, make_taxi_data
from repro.runtime import ExecutorConfig


def run(sizes=(10_000, 100_000, 500_000)) -> List[str]:
    out = []
    for n in sizes:
        rng = np.random.default_rng(0)
        with Client.ephemeral(
            shard_rows=65536, executor_config=ExecutorConfig(max_workers=2)
        ) as client:
            client.write_table("taxi_table", make_taxi_data(n, rng),
                               schema=TAXI_SCHEMA)
            branch_id = [0]

            def run_mode(fusion: bool):
                branch_id[0] += 1
                # cache=False: this benchmark measures genuine recompute
                # cost; the (default-on) differential cache would turn
                # every repeat into a restore and flatten the comparison
                return client.run(
                    build_taxi_pipeline(),
                    branch=f"b{branch_id[0]}_{fusion}",
                    fusion=fusion,
                    pushdown=fusion,
                    cache=False,
                ).raise_for_state()

            t_fused = bench(lambda: run_mode(True), warmup=1, iters=3)
            t_naive = bench(lambda: run_mode(False), warmup=1, iters=3)
            res_f = run_mode(True)
            res_n = run_mode(False)
        speedup = t_naive / t_fused
        io_ratio = (
            res_n.io["bytes_written"] / max(res_f.io["bytes_written"], 1)
        )
        out.append(
            row(
                f"fusion_speedup_n{n}",
                t_fused * 1e6,
                f"naive_us={t_naive * 1e6:.0f};speedup={speedup:.2f}x;"
                f"io_write_ratio={io_ratio:.2f}x;paper_claim=5x",
            )
        )
    return out
