"""Lakekeeper benchmarks: bytes reclaimed by GC, warm-scan speedup from
shard compaction (ISSUE 2 acceptance numbers).

Scenario 1 (gc): the taxi pipeline runs 4 times with an edited filter
date — each edit writes new trips/pickups artifacts, so the lake
accumulates superseded table versions.  ``repro cache prune`` releases
the stale cache roots, ``repro gc --history 1`` expires non-head
history, and the sweep must reclaim >=50% of store bytes while the
branch head stays bit-identical.

Scenario 2 (compact): a table built from many small appends is
compacted; a full warm scan afterwards must touch fewer objects and
finish faster, with identical rows.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import bench, row
from repro.api import Client
from repro.core import Pipeline, requirements
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.io import ObjectStore
from repro.runtime import ExecutorConfig



def _build_pipeline(since: str) -> Pipeline:
    p = Pipeline("taxi_maintenance_bench")
    p.sql(
        "trips",
        f"""
        SELECT pickup_location_id, passenger_count as count, dropoff_location_id
        FROM taxi_table WHERE pickup_at >= '{since}'
        """,
    )

    @p.python
    @requirements({"pandas": "2.0.0"})
    def trips_expectation(ctx, trips):
        return trips.mean("count") > 10.0

    p.sql(
        "pickups",
        """
        SELECT pickup_location_id, dropoff_location_id, COUNT(*) AS counts
        FROM trips GROUP BY pickup_location_id, dropoff_location_id
        ORDER BY counts DESC
        """,
    )
    return p


def _store_bytes(store: ObjectStore) -> int:
    return sum(store.object_size(k) or 0 for k in store.keys())


def _bench_gc(n: int) -> List[str]:
    rng = np.random.default_rng(0)
    dates = ["2019-02-01", "2019-02-05", "2019-02-09", "2019-02-13"]
    with Client.ephemeral(
        shard_rows=16384, executor_config=ExecutorConfig(max_workers=2)
    ) as client:
        client.write_table("taxi_table", make_taxi_data(n, rng),
                           schema=TAXI_SCHEMA)
        for since in dates:
            res = client.run(
                _build_pipeline(since), branch="main",
                fusion=False, pushdown=False, cache=True,
            ).raise_for_state()
        baseline = client.query("SELECT pickup_location_id, counts FROM pickups")

        store = client.store
        before = _store_bytes(store)
        budget = sum(
            e.output_bytes for e in client.cache.stats()["items"].values()
            if e.run_id == res.run_id
        )
        client.cache.prune(max_bytes=budget)
        t0 = time.perf_counter()
        report = client.gc(history=1, grace_s=0.0)
        gc_wall = time.perf_counter() - t0
        after = _store_bytes(store)

        out = client.query("SELECT pickup_location_id, counts FROM pickups")
        assert np.array_equal(out["counts"], baseline["counts"]), "gc broke the head!"
        warm = client.run(
            _build_pipeline(dates[-1]), branch="main",
            fusion=False, pushdown=False, cache=True,
        ).raise_for_state()

    frac = 1.0 - after / before
    assert frac >= 0.5, f"gc only reclaimed {frac:.1%} (target >=50%)"
    return [
        row(
            f"gc_taxi_4edited_runs_n{n}",
            gc_wall * 1e6,
            f"reclaimed={report.bytes_reclaimed}B;frac={frac:.1%};"
            f"objects={report.swept_objects};commits={report.swept_commits};"
            f"target>=50%",
        ),
        row(
            f"gc_post_sweep_warm_run_n{n}",
            0.0,
            f"cache_hits={warm.cache['hits']};"
            f"stages_executed={warm.cache['stages_executed']};"
            f"head_bit_identical=True",
        ),
    ]


def _bench_compaction(n: int, append_rows: int) -> List[str]:
    client = Client.ephemeral(shard_rows=max(n, 1))
    store, catalog, fmt = client.store, client.catalog, client.fmt
    rng = np.random.default_rng(1)
    data = make_taxi_data(n, rng)
    for start in range(0, n, append_rows):
        chunk = {c: v[start:start + append_rows] for c, v in data.items()}
        client.write_table("taxi_table", chunk, schema=TAXI_SCHEMA,
                           append=start > 0)

    def scan():
        key = catalog.table_key("taxi_table")
        fmt.read(fmt.load_snapshot(key))

    gets0 = store.stats.gets
    t_before = bench(scan, warmup=1, iters=5)
    gets_before = (store.stats.gets - gets0) // 6

    report = client.compact("taxi_table")[0]
    fragmented = fmt.read(fmt.load_snapshot(
        catalog.table_key("taxi_table", commit_id=catalog.head("main").parent_id)
    ))
    compacted = fmt.read(fmt.load_snapshot(catalog.table_key("taxi_table")))
    for col in TAXI_SCHEMA.names:
        assert np.array_equal(fragmented[col], compacted[col]), "compaction changed data!"

    gets0 = store.stats.gets
    t_after = bench(scan, warmup=1, iters=5)
    gets_after = (store.stats.gets - gets0) // 6

    speedup = t_before / max(t_after, 1e-9)
    assert report.shards_after < report.shards_before, "no shards merged"
    return [
        row(
            f"compact_scan_fragmented_n{n}",
            t_before * 1e6,
            f"shards={report.shards_before};gets_per_scan={gets_before}",
        ),
        row(
            f"compact_scan_compacted_n{n}",
            t_after * 1e6,
            f"shards={report.shards_after};gets_per_scan={gets_after};"
            f"speedup={speedup:.2f}x;bit_identical=True",
        ),
    ]


def run(n: int = 200_000) -> List[str]:
    out = _bench_gc(n)
    out += _bench_compaction(n // 2, append_rows=1000)
    return out
