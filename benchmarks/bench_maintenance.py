"""Lakekeeper benchmarks: bytes reclaimed by GC, warm-scan speedup from
shard compaction (ISSUE 2 acceptance numbers).

Scenario 1 (gc): the taxi pipeline runs 4 times with an edited filter
date — each edit writes new trips/pickups artifacts, so the lake
accumulates superseded table versions.  ``repro cache prune`` releases
the stale cache roots, ``repro gc --history 1`` expires non-head
history, and the sweep must reclaim >=50% of store bytes while the
branch head stays bit-identical.

Scenario 2 (compact): a table built from many small appends is
compacted; a full warm scan afterwards must touch fewer objects and
finish faster, with identical rows.
"""
from __future__ import annotations

import tempfile
import time
from typing import List

import numpy as np

from benchmarks.common import bench, row
from repro.catalog import Catalog
from repro.core import Pipeline, Runner, StageCacheRegistry, requirements
from repro.io import ObjectStore
from repro.maintenance import EvictionPolicy, collect_garbage, compact_table, prune_cache
from repro.runtime import ExecutorConfig, ServerlessExecutor
from repro.table import Schema, TableFormat

TAXI_SCHEMA = Schema.of(
    pickup_at="int32",
    pickup_location_id="int32",
    passenger_count="int32",
    dropoff_location_id="int32",
)
APRIL_1 = 17987


def _make_data(n: int, rng: np.random.Generator):
    days = np.sort(rng.integers(APRIL_1 - 60, APRIL_1 + 30, n)).astype(np.int32)
    return {
        "pickup_at": days,
        "pickup_location_id": rng.integers(0, 64, n).astype(np.int32),
        "passenger_count": rng.poisson(30.0, n).astype(np.int32),
        "dropoff_location_id": rng.integers(0, 64, n).astype(np.int32),
    }


def _build_pipeline(since: str) -> Pipeline:
    p = Pipeline("taxi_maintenance_bench")
    p.sql(
        "trips",
        f"""
        SELECT pickup_location_id, passenger_count as count, dropoff_location_id
        FROM taxi_table WHERE pickup_at >= '{since}'
        """,
    )

    @p.python
    @requirements({"pandas": "2.0.0"})
    def trips_expectation(ctx, trips):
        return trips.mean("count") > 10.0

    p.sql(
        "pickups",
        """
        SELECT pickup_location_id, dropoff_location_id, COUNT(*) AS counts
        FROM trips GROUP BY pickup_location_id, dropoff_location_id
        ORDER BY counts DESC
        """,
    )
    return p


def _store_bytes(store: ObjectStore) -> int:
    return sum(store.object_size(k) or 0 for k in store.keys())


def _bench_gc(n: int) -> List[str]:
    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store, shard_rows=16384)
    rng = np.random.default_rng(0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, _make_data(n, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})

    dates = ["2019-02-01", "2019-02-05", "2019-02-09", "2019-02-13"]
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        runner = Runner(catalog, fmt, ex)
        for since in dates:
            res = runner.run(
                _build_pipeline(since), branch="main",
                fusion=False, pushdown=False, cache=True,
            )
        baseline = runner.query("SELECT pickup_location_id, counts FROM pickups")

        before = _store_bytes(store)
        registry = StageCacheRegistry(store)
        budget = sum(
            e.output_bytes for e in registry.entries().values()
            if e.run_id == res.run_id
        )
        prune_cache(registry, EvictionPolicy(max_bytes=budget))
        t0 = time.perf_counter()
        report = collect_garbage(store, catalog, fmt, history=1, grace_s=0.0)
        gc_wall = time.perf_counter() - t0
        after = _store_bytes(store)

        out = runner.query("SELECT pickup_location_id, counts FROM pickups")
        assert np.array_equal(out["counts"], baseline["counts"]), "gc broke the head!"
        warm = runner.run(
            _build_pipeline(dates[-1]), branch="main",
            fusion=False, pushdown=False, cache=True,
        )

    frac = 1.0 - after / before
    assert frac >= 0.5, f"gc only reclaimed {frac:.1%} (target >=50%)"
    return [
        row(
            f"gc_taxi_4edited_runs_n{n}",
            gc_wall * 1e6,
            f"reclaimed={report.bytes_reclaimed}B;frac={frac:.1%};"
            f"objects={report.swept_objects};commits={report.swept_commits};"
            f"target>=50%",
        ),
        row(
            f"gc_post_sweep_warm_run_n{n}",
            0.0,
            f"cache_hits={warm.stats['cache']['hits']};"
            f"stages_executed={warm.stats['cache']['stages_executed']};"
            f"head_bit_identical=True",
        ),
    ]


def _bench_compaction(n: int, append_rows: int) -> List[str]:
    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store, shard_rows=max(n, 1))
    rng = np.random.default_rng(1)
    data = _make_data(n, rng)
    snap = None
    for start in range(0, n, append_rows):
        chunk = {c: v[start:start + append_rows] for c, v in data.items()}
        snap = fmt.write(
            "taxi_table", TAXI_SCHEMA, chunk,
            parent=snap, append=snap is not None,
        )
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})

    def scan():
        key = catalog.table_key("taxi_table")
        fmt.read(fmt.load_snapshot(key))

    gets0 = store.stats.gets
    t_before = bench(scan, warmup=1, iters=5)
    gets_before = (store.stats.gets - gets0) // 6

    report = compact_table(catalog, fmt, "taxi_table")
    fragmented = fmt.read(fmt.load_snapshot(
        catalog.table_key("taxi_table", commit_id=catalog.head("main").parent_id)
    ))
    compacted = fmt.read(fmt.load_snapshot(catalog.table_key("taxi_table")))
    for col in TAXI_SCHEMA.names:
        assert np.array_equal(fragmented[col], compacted[col]), "compaction changed data!"

    gets0 = store.stats.gets
    t_after = bench(scan, warmup=1, iters=5)
    gets_after = (store.stats.gets - gets0) // 6

    speedup = t_before / max(t_after, 1e-9)
    assert report.shards_after < report.shards_before, "no shards merged"
    return [
        row(
            f"compact_scan_fragmented_n{n}",
            t_before * 1e6,
            f"shards={report.shards_before};gets_per_scan={gets_before}",
        ),
        row(
            f"compact_scan_compacted_n{n}",
            t_after * 1e6,
            f"shards={report.shards_after};gets_per_scan={gets_after};"
            f"speedup={speedup:.2f}x;bit_identical=True",
        ),
    ]


def run(n: int = 200_000) -> List[str]:
    out = _bench_gc(n)
    out += _bench_compaction(n // 2, append_rows=1000)
    return out
