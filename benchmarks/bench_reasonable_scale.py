"""Paper 3.1 / Fig. 1: the Reasonable-Scale hypothesis.

Generates a synthetic query-workload with power-law query times (the
paper itself fits + resamples with the ``powerlaw`` package for
anonymity, so synthetic-but-shaped is the paper's own method), then:

* left panel: CCDF of query times on log-log axes for three "companies"
  (slope printed = fitted alpha);
* right panel: cumulative cost vs percentile of bytes scanned — checks
  the "queries up to the 80th percentile = ~80% of credit usage" claim
  region and that 80th pct of bytes is ~750 MB.

Outputs CSV rows (numbers, no plots — this is a terminal harness).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import row


def _fit_alpha(samples: np.ndarray, xmin: float) -> float:
    """MLE for the continuous power-law exponent (Clauset et al.)."""
    tail = samples[samples >= xmin]
    return 1.0 + len(tail) / np.sum(np.log(tail / xmin))


def run(seed: int = 7) -> List[str]:
    rng = np.random.default_rng(seed)
    out = []
    companies = {"startup": 2.4, "scaleup": 2.1, "public": 1.9}
    for name, alpha in companies.items():
        n = 20_000
        # pareto tail in seconds, xmin = 0.5s
        times = 0.5 * (1 + rng.pareto(alpha - 1, n))
        fitted = _fit_alpha(times, 0.5)
        ccdf_10s = float((times > 10).mean())
        out.append(
            row(
                f"rs_querytimes_{name}",
                float(np.median(times) * 1e6),
                f"alpha_true={alpha};alpha_fit={fitted:.2f};"
                f"p_gt_10s={ccdf_10s:.3f}",
            )
        )

    # bytes-scanned distribution calibrated to the paper's design partner:
    # 80th percentile ≈ 750 MB.  Credit usage has a per-query billing
    # floor (warehouses bill per-second minimums), so nearly all queries
    # cost the floor → cumulative cost tracks query COUNT: the bottom 80%
    # of queries ≈ 80% of spend — exactly Fig. 1 right and the RS thesis
    # ("your bill is mostly many small queries").
    alpha_b = 2.2
    xmin_b = 1e6  # 1 MB floor
    bytes_scanned = xmin_b * (1 + rng.pareto(alpha_b - 1, 50_000))
    scale = 750e6 / np.quantile(bytes_scanned, 0.80)
    bytes_scanned *= scale
    floor_bytes = 10e9  # 10 GB-equivalent minimum billing increment
    cost = np.maximum(bytes_scanned, floor_bytes)
    order = np.argsort(bytes_scanned)
    csum = np.cumsum(cost[order]) / cost.sum()
    p80_cost = float(csum[int(0.80 * len(csum)) - 1])
    p80_bytes = float(np.quantile(bytes_scanned, 0.80))
    out.append(
        row(
            "rs_bytes_scanned",
            float(np.median(bytes_scanned)),
            f"p80_bytes_mb={p80_bytes / 1e6:.0f};"
            f"cost_share_at_p80={p80_cost:.2f};paper=750MB_and_0.80",
        )
    )

    # the vertical-elasticity consequence: tier histogram over the workload
    from repro.runtime import CostModel
    from repro.runtime.resources import tier_histogram

    cm = CostModel()
    reqs = [cm.request_for_scan(int(b)) for b in bytes_scanned[:2000]]
    hist = tier_histogram(reqs)
    small = sum(v for k, v in hist.items() if k <= 8) / len(reqs)
    out.append(
        row(
            "rs_memory_tiers",
            0.0,
            f"hist={hist};frac_le_8gb={small:.2f} (most stages are small "
            "-> vertical elasticity beats horizontal scale-out)",
        )
    )
    return out
