"""Cross-run differential artifact cache (FaaS & Furious, arXiv 2411.08203).

The claim under test: on a re-run of the taxi pipeline, stages whose
transitive fingerprint is unchanged restore from the object store instead
of recomputing, so

* a fully-warm re-run executes 0 stages;
* a re-run with ONE edited node executes only the dirty cone;
* warm wall-clock is >= 2x faster than cold.

Cold/warm/edited runs use the isomorphic (fusion-off) plan so the cache
unit is one node per stage — the differential granularity the follow-up
paper argues for.
"""
from __future__ import annotations

import tempfile
import time
from typing import List

import numpy as np

from benchmarks.common import row
from repro.catalog import Catalog
from repro.core import Pipeline, Runner, requirements
from repro.io import ObjectStore
from repro.runtime import ExecutorConfig, ServerlessExecutor
from repro.table import Schema, TableFormat

TAXI_SCHEMA = Schema.of(
    pickup_at="int32",
    pickup_location_id="int32",
    passenger_count="int32",
    dropoff_location_id="int32",
)
APRIL_1 = 17987  # days since epoch for 2019-04-01


def _make_data(n: int, rng: np.random.Generator):
    days = np.sort(rng.integers(APRIL_1 - 60, APRIL_1 + 30, n)).astype(np.int32)
    return {
        "pickup_at": days,
        "pickup_location_id": rng.integers(0, 64, n).astype(np.int32),
        "passenger_count": rng.poisson(30.0, n).astype(np.int32),
        "dropoff_location_id": rng.integers(0, 64, n).astype(np.int32),
    }


def _build_pipeline(order: str = "DESC") -> Pipeline:
    """The Appendix taxi DAG; ``order`` parameterizes the terminal node so
    the benchmark can edit exactly one node between runs."""
    p = Pipeline("taxi_cache_bench")
    p.sql(
        "trips",
        """
        SELECT pickup_location_id, passenger_count as count, dropoff_location_id
        FROM taxi_table
        WHERE pickup_at >= '2019-04-01'
        """,
    )

    @p.python
    @requirements({"pandas": "2.0.0"})
    def trips_expectation(ctx, trips):
        return trips.mean("count") > 10.0

    p.sql(
        "pickups",
        f"""
        SELECT pickup_location_id, dropoff_location_id, COUNT(*) AS counts
        FROM trips
        GROUP BY pickup_location_id, dropoff_location_id
        ORDER BY counts {order}
        """,
    )
    return p


def run(n: int = 400_000) -> List[str]:
    store = ObjectStore(tempfile.mkdtemp())
    catalog = Catalog(store)
    fmt = TableFormat(store, shard_rows=65536)
    rng = np.random.default_rng(0)
    snap = fmt.write("taxi_table", TAXI_SCHEMA, _make_data(n, rng))
    catalog.commit("main", {"taxi_table": fmt.manifest_key(snap)})

    def timed_run(runner, pipeline, branch):
        t0 = time.perf_counter()
        res = runner.run(
            pipeline, branch=branch, fusion=False, pushdown=False, cache=True
        )
        return time.perf_counter() - t0, res

    out: List[str] = []
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        runner = Runner(catalog, fmt, ex)
        t_cold, cold = timed_run(runner, _build_pipeline(), "cold")
        t_warm, warm = timed_run(runner, _build_pipeline(), "warm")
        t_edit, edit = timed_run(runner, _build_pipeline(order="ASC"), "edited")

    c, w, e = (r.stats["cache"] for r in (cold, warm, edit))
    speedup_warm = t_cold / max(t_warm, 1e-9)
    speedup_edit = t_cold / max(t_edit, 1e-9)
    assert w["stages_executed"] < c["stages_executed"], "warm must skip stages"
    assert e["stages_executed"] == 1, "one edited node -> one dirty stage"
    out.append(
        row(
            f"diffcache_cold_n{n}",
            t_cold * 1e6,
            f"stages_executed={c['stages_executed']};hits={c['hits']}",
        )
    )
    out.append(
        row(
            f"diffcache_warm_n{n}",
            t_warm * 1e6,
            f"stages_executed={w['stages_executed']};hits={w['hits']};"
            f"speedup={speedup_warm:.2f}x;bytes_saved={w['bytes_saved']};"
            f"target>=2x",
        )
    )
    out.append(
        row(
            f"diffcache_edited_node_n{n}",
            t_edit * 1e6,
            f"stages_executed={e['stages_executed']};hits={e['hits']};"
            f"speedup={speedup_edit:.2f}x;dirty_cone_only=True",
        )
    )
    return out
