"""Cross-run differential artifact cache (FaaS & Furious, arXiv 2411.08203).

Constructed entirely through the SDK facade (``repro.api.Client``) — the
benchmark is also a smoke test of the one-construction-path invariant.

The claim under test: the cache is keyed at **logical-node** granularity
(node code + upstream node fingerprints + input content hashes + params),
independent of the physical planner's fusion grouping, so

* a fully-warm re-run executes 0 nodes;
* a re-run with ONE edited node executes only that node's downstream cone;
* **flipping the planner config on a warm lake — fusion toggled or
  ``max_stage_nodes`` changed — still executes 0 nodes** (under the old
  stage-keyed scheme this was a guaranteed full recompute);
* warm wall-clock is >= 2x faster than cold.

Cold/warm/edited runs use the isomorphic (fusion-off) plan so every node
is materialized and the dirty-cone accounting is visible node by node;
the flip scenarios then re-plan the same warm lake fused.

Also runnable standalone for the CI smoke-benchmark job::

    python -m benchmarks.bench_differential_cache --n 20000 --json out.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import perf_meta, row
from repro.api import Client
from repro.core import Pipeline, PlannerConfig, requirements
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.runtime import ExecutorConfig



def _build_pipeline(order: str = "DESC") -> Pipeline:
    """The Appendix taxi DAG; ``order`` parameterizes the terminal node so
    the benchmark can edit exactly one node between runs."""
    p = Pipeline("taxi_cache_bench")
    p.sql(
        "trips",
        """
        SELECT pickup_location_id, passenger_count as count, dropoff_location_id
        FROM taxi_table
        WHERE pickup_at >= '2019-04-01'
        """,
    )

    @p.python
    @requirements({"pandas": "2.0.0"})
    def trips_expectation(ctx, trips):
        return trips.mean("count") > 10.0

    p.sql(
        "pickups",
        f"""
        SELECT pickup_location_id, dropoff_location_id, COUNT(*) AS counts
        FROM trips
        GROUP BY pickup_location_id, dropoff_location_id
        ORDER BY counts {order}
        """,
    )
    return p


def run(n: int = 400_000, json_path: Optional[str] = None) -> List[str]:
    rng = np.random.default_rng(0)

    def timed_run(client, pipeline, branch, **kw):
        kw.setdefault("fusion", False)
        kw.setdefault("pushdown", False)
        t0 = time.perf_counter()
        res = client.run(pipeline, branch=branch, cache=True, **kw)
        res.raise_for_state()
        return time.perf_counter() - t0, res

    with Client.ephemeral(
        shard_rows=65536,
        executor_config=ExecutorConfig(max_workers=2, max_concurrent_stages=2),
    ) as client:
        client.write_table("taxi_table", make_taxi_data(n, rng),
                           schema=TAXI_SCHEMA)
        t_cold, cold = timed_run(client, _build_pipeline(), "cold")
        t_warm, warm = timed_run(client, _build_pipeline(), "warm")
        t_edit, edit = timed_run(client, _build_pipeline(order="ASC"), "edited")
        # the tentpole scenarios: flip the planner config on the warm lake
        t_flip, flip = timed_run(
            client, _build_pipeline(), "flip_fused", fusion=True, pushdown=True
        )
        t_cap, cap = timed_run(
            client, _build_pipeline(), "flip_capped",
            planner_config=PlannerConfig(fusion=True, max_stage_nodes=1),
        )

    stats = {
        name: r.cache
        for name, r in (
            ("cold", cold), ("warm", warm), ("edited", edit),
            ("fusion_flip", flip), ("max_stage_nodes_flip", cap),
        )
    }
    c, w, e = stats["cold"], stats["warm"], stats["edited"]
    speedup_warm = t_cold / max(t_warm, 1e-9)
    speedup_edit = t_cold / max(t_edit, 1e-9)
    assert w["nodes_executed"] == 0, "warm re-run must execute nothing"
    assert e["nodes_executed"] == 1, "one edited node -> only its dirty cone"
    # acceptance: a planner-config change on the warm lake is still warm
    assert stats["fusion_flip"]["nodes_executed"] == 0, (
        "fusion flip must execute 0 nodes"
    )
    assert stats["max_stage_nodes_flip"]["nodes_executed"] == 0, (
        "max_stage_nodes flip must execute 0 nodes"
    )

    out: List[str] = []
    walls = {
        "cold": t_cold, "warm": t_warm, "edited": t_edit,
        "fusion_flip": t_flip, "max_stage_nodes_flip": t_cap,
    }
    out.append(
        row(
            f"diffcache_cold_n{n}",
            t_cold * 1e6,
            f"nodes_executed={c['nodes_executed']};hits={c['hits']}",
        )
    )
    out.append(
        row(
            f"diffcache_warm_n{n}",
            t_warm * 1e6,
            f"nodes_executed={w['nodes_executed']};hits={w['hits']};"
            f"speedup={speedup_warm:.2f}x;bytes_saved={w['bytes_saved']};"
            f"target>=2x",
        )
    )
    out.append(
        row(
            f"diffcache_edited_node_n{n}",
            t_edit * 1e6,
            f"nodes_executed={e['nodes_executed']};hits={e['hits']};"
            f"speedup={speedup_edit:.2f}x;dirty_cone_only=True",
        )
    )
    for scenario in ("fusion_flip", "max_stage_nodes_flip"):
        s = stats[scenario]
        out.append(
            row(
                f"diffcache_{scenario}_n{n}",
                walls[scenario] * 1e6,
                f"nodes_executed={s['nodes_executed']};hits={s['hits']};"
                f"rehydrated={s['rehydrated']};elided={s['elided']};"
                f"speedup={t_cold / max(walls[scenario], 1e-9):.2f}x;"
                f"warm_under_changed_config=True",
            )
        )

    if json_path is not None:
        results = {
            "n": n,
            # perf-trajectory comparability: this bench runs its stages
            # through the wave scheduler at the executor's configured
            # concurrency (see benchmarks/common.perf_meta)
            "parallelism": 2,
            "scenarios": {
                name: {
                    **perf_meta(parallelism=2, wall_s=walls[name]),
                    "hits": s["hits"],
                    "nodes_executed": s["nodes_executed"],
                    "rehydrated": s["rehydrated"],
                    "elided": s["elided"],
                    "bytes_saved": s["bytes_saved"],
                }
                for name, s in stats.items()
            },
            "speedup_warm": speedup_warm,
            "speedup_edited": speedup_edit,
        }
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=400_000, help="taxi rows")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write scenario metrics as JSON (CI artifact)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(n=args.n, json_path=args.json):
        print(line, flush=True)


if __name__ == "__main__":
    main()
