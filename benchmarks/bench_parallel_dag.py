"""Wave-parallel DAG execution: wall-clock speedup from concurrent stages.

The paper's serverless pitch is that independent pipeline work fans out
across function invocations.  This benchmark pins the wave scheduler's
share of that claim with two scenarios:

* **fan_out** — an 8-way fan-out pipeline (independent "model" nodes over
  the taxi fixture, each invoking an external scorer — a host callback
  with realistic remote-inference latency, the serverless analog of
  bench_speculation's straggler sleeps) executed at parallelism 1, 2, 4
  and 8.  Acceptance: **>= 2x wall-clock at parallelism >= 4 vs the
  sequential (parallelism 1) run**, with byte-identical artifact
  manifests at every level — parallelism is a throughput knob, never a
  semantics knob.
* **wide_scan** — ``execute_scan`` over a deliberately many-sharded
  snapshot with object-store GET latency restored (the paper's lake is
  S3; the local stand-in hides the round trip the pool overlaps), serial
  vs pooled shard reads.

Also runnable standalone for the CI smoke-bench job::

    python -m benchmarks.bench_parallel_dag --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import perf_meta, row
from repro.api import Client
from repro.core import Pipeline
from repro.examples_data import TAXI_SCHEMA, make_taxi_data
from repro.io import ObjectStore
from repro.runtime import ExecutorConfig
from repro.table import Predicate, TableFormat, execute_scan, plan_scan

#: fan-out width (the paper's "independent models" count)
FAN_OUT = 8
#: parallelism levels measured; 1 is the sequential baseline
LEVELS = (1, 2, 4, 8)


def _external_scorer(latency_s: float, salt: int):
    """Simulated remote model inference: a host-side callback with
    invocation latency.  Deterministic in its inputs — the top-k sum is a
    stand-in for a model score — so artifacts stay byte-identical across
    parallelism levels while the *latency* (the serverless cost the wave
    scheduler overlaps) stays realistic."""

    def scorer(counts: np.ndarray) -> np.ndarray:
        time.sleep(latency_s)
        top = np.sort(np.asarray(counts, dtype=np.float32))[-32:]
        return np.float32(top.sum() + salt)

    return scorer


def build_fanout_pipeline(k: int = FAN_OUT, *, latency_s: float = 0.12) -> Pipeline:
    """``k`` independent model nodes over the taxi fixture — every stage
    is unblocked from the start, the wave scheduler's best case."""
    p = Pipeline("parallel_dag_bench")
    for i in range(k):

        def make_model(i: int):
            def fn(ctx, taxi_table):
                counts = taxi_table.column("passenger_count")
                score = jax.pure_callback(
                    _external_scorer(latency_s, i),
                    jax.ShapeDtypeStruct((), jnp.float32),
                    counts,
                )
                return {"score": score[None]}

            fn.__name__ = f"model_{i}"
            return fn

        p.python(make_model(i))
    return p


def _run_level(
    data: Dict[str, np.ndarray], pipeline: Pipeline, parallelism: int
) -> Dict:
    """One fresh lake, one cold run at ``parallelism`` stages in flight."""
    with Client.ephemeral(
        executor_config=ExecutorConfig(
            max_workers=max(4, FAN_OUT),
            max_concurrent_stages=parallelism,
        ),
    ) as client:
        client.write_table("taxi_table", data, schema=TAXI_SCHEMA)
        t0 = time.perf_counter()
        handle = client.run(pipeline, cache=False, parallelism=parallelism)
        wall = time.perf_counter() - t0
        handle.raise_for_state()
        return {
            "wall_s": wall,
            "artifacts": dict(handle.artifacts),
            "stages_executed": handle.stats["stages_executed"],
            "reported_parallelism": handle.stats["parallelism"],
        }


class _S3LikeStore(ObjectStore):
    """The local stand-in with object-store GET latency layered back on.

    The paper's lake lives on S3 where every blob GET pays a network
    round trip — exactly the latency parallel shard reads overlap.  The
    local filesystem hides it (reads are page-cache memcpys, where a
    thread pool is a wash), so the wide-scan scenario restores a
    conservative per-GET cost to measure what production would see.
    """

    GET_LATENCY_S = 0.004

    def get(self, key: str) -> bytes:
        time.sleep(self.GET_LATENCY_S)
        return super().get(key)


def _wide_scan(n: int, rng: np.random.Generator) -> Dict:
    """Serial vs pooled shard reads over a many-sharded snapshot."""
    import tempfile
    from concurrent.futures import ThreadPoolExecutor

    from benchmarks.common import bench

    n_scan = max(n * 4, 400_000)
    shard_rows = max(4096, n_scan // 32)  # ~32 substantial shards
    fmt = TableFormat(
        _S3LikeStore(tempfile.mkdtemp(prefix="repro_scanbench_")),
        shard_rows=shard_rows,
    )
    snap = fmt.write("taxi_table", TAXI_SCHEMA, make_taxi_data(n_scan, rng))
    plan = plan_scan(
        snap,
        columns=["pickup_location_id", "passenger_count"],
        predicates=[Predicate("passenger_count", ">", 5)],
    )
    with ThreadPoolExecutor(max_workers=8, thread_name_prefix="scan") as pool:
        serial = execute_scan(fmt, plan)
        pooled = execute_scan(fmt, plan, pool=pool)
        for c in serial:
            np.testing.assert_array_equal(serial[c], pooled[c])
        assert set(serial) == {"pickup_location_id", "passenger_count"}, (
            "scan must return only the projection"
        )
        t_serial = bench(lambda: execute_scan(fmt, plan), warmup=1, iters=3)
        t_pooled = bench(
            lambda: execute_scan(fmt, plan, pool=pool), warmup=1, iters=3
        )
    speedup = t_serial / max(t_pooled, 1e-9)
    assert speedup >= 1.5, (
        f"pooled wide scan speedup {speedup:.2f}x < 1.5x sanity floor"
    )
    return {
        "rows": n_scan,
        "shards": len(plan.shards),
        "get_latency_s": _S3LikeStore.GET_LATENCY_S,
        "serial_wall_s": t_serial,
        "pooled_wall_s": t_pooled,
        "speedup": speedup,
    }


def run(
    n: int = 200_000,
    latency_s: float = 0.12,
    json_path: Optional[str] = None,
) -> List[str]:
    rng = np.random.default_rng(0)
    data = make_taxi_data(n, rng)

    levels: Dict[int, Dict] = {}
    for parallelism in LEVELS:
        levels[parallelism] = _run_level(
            data, build_fanout_pipeline(latency_s=latency_s), parallelism
        )

    sequential = levels[1]
    for parallelism, res in levels.items():
        assert res["artifacts"] == sequential["artifacts"], (
            f"parallelism {parallelism} changed artifact manifests — "
            "parallelism must never be a semantics knob"
        )
        assert res["stages_executed"] == FAN_OUT

    speedups = {
        p: sequential["wall_s"] / max(res["wall_s"], 1e-9)
        for p, res in levels.items()
    }
    # acceptance: the 8-way fan-out is >= 2x faster at parallelism >= 4
    assert speedups[4] >= 2.0, (
        f"parallelism 4 speedup {speedups[4]:.2f}x < 2x acceptance target"
    )

    scan = _wide_scan(n, rng)

    out: List[str] = []
    for parallelism, res in sorted(levels.items()):
        out.append(
            row(
                f"parallel_dag_fanout{FAN_OUT}_p{parallelism}_n{n}",
                res["wall_s"] * 1e6,
                f"speedup={speedups[parallelism]:.2f}x;"
                f"stages={res['stages_executed']};target>=2x@p>=4;"
                f"identical_artifacts=True",
            )
        )
    out.append(
        row(
            f"parallel_dag_wide_scan_{scan['shards']}shards_n{scan['rows']}",
            scan["pooled_wall_s"] * 1e6,
            f"serial={scan['serial_wall_s'] * 1e6:.0f}us;"
            f"speedup={scan['speedup']:.2f}x;parallel_shard_reads=True;"
            f"s3_like_get={scan['get_latency_s'] * 1e3:.0f}ms",
        )
    )

    if json_path is not None:
        results = {
            "n": n,
            "fan_out": FAN_OUT,
            "invoke_latency_s": latency_s,
            "scenarios": {
                f"fanout_p{p}": {
                    **perf_meta(
                        parallelism=p,
                        wall_s=res["wall_s"],
                        sequential_wall_s=sequential["wall_s"],
                    ),
                    "stages_executed": res["stages_executed"],
                }
                for p, res in sorted(levels.items())
            },
            "wide_scan": scan,
            "speedup_at_parallelism_4": speedups[4],
            "speedup_at_parallelism_8": speedups[8],
        }
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=200_000, help="taxi rows")
    ap.add_argument("--latency-ms", type=float, default=120.0,
                    help="simulated remote-inference latency per model")
    ap.add_argument("--smoke", action="store_true",
                    help="small fixture + shorter latencies (CI smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write scenario metrics as JSON (CI artifact)")
    args = ap.parse_args()
    # smoke keeps the fixture small but the invocation latency realistic:
    # the speedup target needs latency (what the scheduler overlaps) to
    # dominate fixed overhead even on a loaded 2-core CI runner
    n = 50_000 if args.smoke else args.n
    latency_s = (140.0 if args.smoke else args.latency_ms) / 1e3
    print("name,us_per_call,derived")
    for line in run(n=n, latency_s=latency_s, json_path=args.json):
        print(line, flush=True)


if __name__ == "__main__":
    main()
