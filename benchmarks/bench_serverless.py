"""Paper 4.5: warm vs cold function start (the 300 ms container claim).

Cold = trace + XLA-compile a pipeline stage; warm = cache hit on the same
(fingerprint, shapes).  Also measures the executor's per-task overhead
(submission → result for a no-op function) — the "serverless tax".
"""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench, row
from repro.engine import Columnar, Query, col
from repro.engine.exec import execute_query
from repro.runtime import ExecutorConfig, FunctionSpec, ServerlessExecutor, WarmFunctionCache


def run() -> List[str]:
    out = []
    rng = np.random.default_rng(0)
    rel = Columnar.from_numpy(
        {
            "k": rng.integers(0, 64, 100_000).astype(np.int32),
            "v": rng.random(100_000).astype(np.float32),
        }
    )
    q = Query("t").where(col("v") > 0.5).group_by("k").agg("sum", col("v"), "s")

    def stage(r):
        return execute_query(q, r)

    # cold starts: fresh cache each time
    cold_times = []
    for i in range(3):
        cache = WarmFunctionCache()
        spec = FunctionSpec(name=f"stage{i}", fn=stage, static_config={"i": i})
        t0 = time.perf_counter()
        fn = cache.get_or_compile(spec, rel)
        cold_times.append(time.perf_counter() - t0)
    cold = sorted(cold_times)[1]

    cache = WarmFunctionCache()
    spec = FunctionSpec(name="warm", fn=stage)
    fn = cache.get_or_compile(spec, rel)

    def warm_call():
        cache.get_or_compile(spec, rel)(rel)

    warm = bench(warm_call, warmup=2, iters=10)
    out.append(
        row(
            "serverless_cold_start",
            cold * 1e6,
            f"warm_us={warm * 1e6:.0f};ratio={cold / max(warm, 1e-9):.1f}x;"
            "paper_cold=spark_cluster_start;paper_warm=300ms",
        )
    )

    # executor overhead
    with ServerlessExecutor(ExecutorConfig(max_workers=2)) as ex:
        nspec = FunctionSpec(name="noop", fn=lambda x: x, jit=False)
        overhead = bench(lambda: ex.run(nspec, 1), warmup=2, iters=20)
    out.append(row("executor_task_overhead", overhead * 1e6, "noop submit->result"))
    return out
