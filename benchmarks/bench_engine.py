"""Query-engine operator microbenchmarks (the duckdb-of-spare-parts) +
the fused_filter_agg Pallas kernel vs its oracle and vs the engine path.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench, row
from repro.engine import Columnar, Query, col, compile_query


def run(n: int = 1_000_000) -> List[str]:
    out = []
    rng = np.random.default_rng(0)
    rel = Columnar.from_numpy(
        {
            "k": rng.integers(0, 256, n).astype(np.int32),
            "k2": rng.integers(0, 16, n).astype(np.int32),
            "v": rng.random(n).astype(np.float32),
        }
    )
    cases = {
        "filter": Query("t").where(col("v") > 0.5).select("v"),
        "groupby_sum": Query("t").group_by("k").agg("sum", col("v"), "s"),
        "filter_groupby_sort": (
            Query("t").where(col("v") > 0.5).group_by("k")
            .agg("sum", col("v"), "s").count("n").sort("s", desc=True)
        ),
        "multikey_groupby": (
            Query("t").group_by("k", "k2").agg("mean", col("v"), "m")
        ),
    }
    for name, q in cases.items():
        fn = compile_query(q)
        fn(rel)  # compile

        def call(fn=fn):
            jax.block_until_ready(fn(rel).valid)

        t = bench(call, warmup=1, iters=5)
        out.append(row(f"engine_{name}_n{n}", t * 1e6, f"rows_per_s={n / t:.2e}"))

    # Pallas fused kernel (interpret mode on CPU — correctness/structure,
    # not TPU speed) vs the pure-jnp oracle
    from repro.kernels.fused_filter_agg import fused_filter_agg, fused_filter_agg_ref

    keys = jnp.asarray(rng.integers(0, 256, 131072).astype(np.int32))
    vals = jnp.asarray(rng.random(131072).astype(np.float32))
    filt = jnp.asarray(rng.random(131072).astype(np.float32))

    def kernel_call():
        s, c = fused_filter_agg(
            keys, vals, filt, op="ge", threshold=0.5, num_groups=256,
            interpret=True,
        )
        jax.block_until_ready(s)

    def ref_call():
        s, c = fused_filter_agg_ref(
            keys, vals, filt, op="ge", threshold=0.5, num_groups=256
        )
        jax.block_until_ready(s)

    tk = bench(kernel_call, warmup=1, iters=3)
    tr = bench(ref_call, warmup=1, iters=3)
    out.append(
        row(
            "kernel_fused_filter_agg_131k",
            tk * 1e6,
            f"ref_us={tr * 1e6:.0f};interpret_mode=structural_check",
        )
    )
    return out
